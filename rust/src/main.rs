//! `isospark` — launcher CLI for the Spark-Isomap reproduction.
//!
//! Subcommands:
//!   run             end-to-end Isomap on a generated dataset
//!   landmark        approximate L-Isomap variant
//!   scale-table     regenerate Tables I–III (simulated paper testbed)
//!   blocksize-sweep regenerate Fig. 6 (block-size sensitivity)
//!   emnist          synthetic-EMNIST embedding + factor analysis (Fig. 5)
//!   fit             fit a streaming model and save the artifact to disk
//!   serve           serve a saved model over HTTP (out-of-sample embedding)
//!   worker          stage-task worker process for distributed runs
//!   bench-serve     loopback load generator against an in-process server
//!   info            artifact inventory / environment report

use anyhow::{bail, Context, Result};
use isospark::backend::Backend;
use isospark::config::{ClusterConfig, IsomapConfig, RawConfig};
use isospark::coordinator::{isomap, landmark};
use isospark::data;
use isospark::eval;
use isospark::sim::{self, CostModel, Workload};
use isospark::util::cli::Args;
use isospark::util::fmt::{human_bytes, human_duration, render_table};
use std::path::{Path, PathBuf};

const USAGE: &str = "\
isospark — exact Isomap on a Spark-like blocked dataflow engine

USAGE: isospark <COMMAND> [OPTIONS]

COMMANDS:
  run              run the pipeline: --dataset swiss|emnist|clusters|s_curve
                   --n <pts> --k <nn> --d <dim> --block <b> --seed <s>
                   --backend native|pjrt --artifacts <dir> --nodes <n>
                   --cores <c> --threads <t> --out <csv> --config <file>
                   --geodesics dense-fw|sparse-dijkstra (sparse: CSR graph
                    + pooled multi-source Dijkstra, no dense APSP RDD)
                   --knn exact|rp-forest (rp-forest: seeded random-
                    projection-forest candidates + exact rescoring —
                    O(T·n·leaf) instead of O(n²) distance FLOPs; tune with
                    --rp-trees <T> (default 8) and --rp-leaf <L>
                    (default 0 = max(4k, 32)))
                   --feature materialized|implicit (implicit: stream b×n
                    geodesic panels per power iteration instead of holding
                    the O(n²) feature blocks — O(n·k + b·n) peak memory,
                    bit-identical embedding; requires --geodesics
                    sparse-dijkstra; with --checkpoint-dir panels spill
                    once and re-read instead of recomputing)
                   (--threads: OS worker threads for real block tasks;
                    0 = all cores. Results are identical for any value.)
                   --fault-rate <p> deterministic fault injection: each
                    task attempt fails (panic or transient error) or
                    straggles with seeded probability p; tasks retry with
                    capped exponential backoff (virtual time only). The
                    embedding is bit-identical to a fault-free run.
                    --fault-seed <s> picks the schedule, --max-attempts
                    <a> bounds retries (default 5)
                   --checkpoint-dir <dir> durable checkpoints: APSP and
                    streaming fits spill checksummed block snapshots and
                    restore from the latest valid one on re-run, skipping
                    completed iterations
                   --workers host:port,... execute the geodesic panel
                    stage on real `isospark worker` processes over the
                    TCP block-shuffle transport (requires --geodesics
                    sparse-dijkstra); the embedding is bit-identical to
                    the single-process run for any worker count, and the
                    report prints measured wall-clock next to the
                    virtual-clock projection. --task-timeout <secs>
                    bounds each response (a slower worker is treated as
                    dead and its tasks retried elsewhere)
  landmark         L-Isomap: same options plus --landmarks <m>
  lle              Locally Linear Embedding (paper §VI extension)
  stream           Streaming-Isomap: fit a batch, map --stream-n new points
  scale-table      Tables I-III: --block <b> --calibrate --nodes-list 2,4,...
  blocksize-sweep  Fig. 6: --n <pts> --dim <D> --nodes <n> --blocks 500,...
  emnist           Fig. 5: --n <pts> --k --d --block, reports factor corrs
  fit              fit a streaming model and save it: dataset options as
                   `run` plus --landmarks <m> --save <dir>
  serve            serve saved models over HTTP: --model <dir> and/or
                   --models name=dir,name=dir --port <p> (0 = ephemeral)
                   --threads <t> | --threads-min <a> --threads-max <b>
                   (pool autoscaling between a and b, driven by queue
                   depth + arrival rate) --max-batch <pts> --batch-min
                   <pts> --target-p95-ms <ms> (adaptive micro-batch cap:
                   grows while the windowed p95 is under target, shrinks
                   over it) --max-queue <reqs> (admission control: 429
                   brown-out near capacity, 503 + Retry-After at it)
                   --host <ip> --port-file <file> --config <file>
                   ([serve] section; flags override). Endpoints:
                   POST /v1/embed {\"points\":[[..],..]}, GET /healthz,
                   GET /metrics, POST /v1/reload {\"path\":\"<dir>\"},
                   GET /v1/models, POST /v1/models/<name>/embed,
                   POST /v1/models/<name>/reload,
                   GET /v1/models/<name>/metrics
  worker           stage-task worker for distributed runs: --listen
                   <ip:port> (port 0 = ephemeral) --threads <t>
                   --port-file <file>; runs until killed, serving any
                   number of driver runs. --die-after-tasks <n> is a
                   test hook: execute n tasks, then drop the connection
                   mid-stage without replying (simulated crash)
  bench-serve      loopback load generator against an in-process server:
                   [--model <dir>] --requests <n> --concurrency <c>
                   --points <per-request> [--json <file>]; reports
                   p50/p95/p99 latency + QPS. --soak holds a QPS target
                   and doubles it (--qps <start> --qps-max <cap>
                   --soak-secs <per-step>) until the server stops keeping
                   up, writes the latency/throughput knee into
                   BENCH_serve.json, and gates on served embeddings being
                   bit-identical to in-process map_points
  info             --artifacts <dir>: artifact + environment report;
                   --model <dir>: inspect a saved model artifact manifest
                   (dims, landmark count, format version, file health);
                   --smoke additionally runs one ragged (b=5) call of
                   every block op through the backend and prints the
                   offload-coverage counters (compiles artifacts)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{USAGE}");
        return;
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(argv[1..].to_vec(), &["calibrate", "lineage", "quiet", "smoke", "soak"])
    {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let out = match cmd.as_str() {
        "run" => cmd_run(&args),
        "landmark" => cmd_landmark(&args),
        "lle" => cmd_lle(&args),
        "stream" => cmd_stream(&args),
        "fit" => cmd_fit(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "bench-serve" => cmd_bench_serve(&args),
        "scale-table" => cmd_scale_table(&args),
        "blocksize-sweep" => cmd_blocksize(&args),
        "emnist" => cmd_emnist(&args),
        "info" => cmd_info(&args),
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = out {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_common(args: &Args) -> Result<(IsomapConfig, ClusterConfig)> {
    let mut iso = IsomapConfig::default();
    let mut cluster = ClusterConfig::local();
    if let Some(path) = args.opt("config") {
        let raw = RawConfig::load(Path::new(path))?;
        iso = raw.isomap()?;
        cluster = raw.cluster()?;
    }
    iso.k = args.get("k", iso.k).map_err(anyhow_str)?;
    iso.d = args.get("d", iso.d).map_err(anyhow_str)?;
    iso.block = args.get("block", iso.block).map_err(anyhow_str)?;
    iso.tol = args.get("tol", iso.tol).map_err(anyhow_str)?;
    iso.max_iter = args.get("max-iter", iso.max_iter).map_err(anyhow_str)?;
    iso.checkpoint_every =
        args.get("checkpoint-every", iso.checkpoint_every).map_err(anyhow_str)?;
    iso.seed = args.get("seed", iso.seed).map_err(anyhow_str)?;
    iso.geodesics = args.get("geodesics", iso.geodesics).map_err(anyhow_str)?;
    iso.knn = args.get("knn", iso.knn).map_err(anyhow_str)?;
    iso.rp_trees = args.get("rp-trees", iso.rp_trees).map_err(anyhow_str)?;
    iso.rp_leaf = args.get("rp-leaf", iso.rp_leaf).map_err(anyhow_str)?;
    iso.feature = args.get("feature", iso.feature).map_err(anyhow_str)?;
    let nodes: usize = args.get("nodes", cluster.nodes).map_err(anyhow_str)?;
    if nodes != cluster.nodes {
        cluster = ClusterConfig::paper_testbed(nodes);
    }
    cluster.cores_per_node = args.get("cores", cluster.cores_per_node).map_err(anyhow_str)?;
    cluster.parallelism = args.get("threads", cluster.parallelism).map_err(anyhow_str)?;
    // Fault-tolerance knobs come after the paper-testbed switch above so
    // `--nodes` never silently wipes an explicit `--fault-rate`.
    cluster.fault_rate = args.get("fault-rate", cluster.fault_rate).map_err(anyhow_str)?;
    if !(0.0..=1.0).contains(&cluster.fault_rate) {
        bail!("--fault-rate must be in [0, 1] (got {})", cluster.fault_rate);
    }
    cluster.fault_seed = args.get("fault-seed", cluster.fault_seed).map_err(anyhow_str)?;
    cluster.fault_max_attempts =
        args.get("max-attempts", cluster.fault_max_attempts).map_err(anyhow_str)?;
    if cluster.fault_max_attempts == 0 {
        bail!("--max-attempts must be ≥ 1");
    }
    if let Some(dir) = args.opt("checkpoint-dir") {
        cluster.checkpoint_dir = Some(dir.to_string());
    }
    if let Some(ws) = args.opt("workers") {
        cluster.dist_workers = isospark::config::parse_worker_list(ws);
        if cluster.dist_workers.is_empty() {
            bail!("--workers: no worker addresses in {ws:?}");
        }
    }
    cluster.dist_task_timeout_secs =
        args.get("task-timeout", cluster.dist_task_timeout_secs).map_err(anyhow_str)?;
    Ok((iso, cluster))
}

fn anyhow_str(e: String) -> anyhow::Error {
    anyhow::anyhow!(e)
}

fn backend_from(args: &Args) -> Result<Backend> {
    match args.opt("backend").unwrap_or("native") {
        "native" => Ok(Backend::Native),
        "pjrt" => {
            let dir = PathBuf::from(args.opt("artifacts").unwrap_or("artifacts"));
            Backend::pjrt_from_dir(&dir).context("load PJRT artifacts")
        }
        other => bail!("unknown backend {other:?} (native|pjrt)"),
    }
}

fn load_dataset(args: &Args) -> Result<data::Dataset> {
    let name = args.opt("dataset").unwrap_or("swiss");
    let n: usize = args.get("n", 1024).map_err(anyhow_str)?;
    let seed: u64 = args.get("seed", 42).map_err(anyhow_str)?;
    data::by_name(name, n, seed)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?} (swiss|emnist|clusters|s_curve)"))
}

fn cmd_run(args: &Args) -> Result<()> {
    let (cfg, cluster) = parse_common(args)?;
    let backend = backend_from(args)?;
    let ds = load_dataset(args)?;
    println!(
        "dataset={} n={} D={} | k={} d={} b={} backend={} | {} node(s) × {} core(s)",
        ds.name,
        ds.n(),
        ds.dim(),
        cfg.k,
        cfg.d,
        cfg.block,
        backend.name(),
        cluster.nodes,
        cluster.cores_per_node
    );
    let sw = isospark::util::Stopwatch::start();
    let out = isomap::run_with(&ds.points, &cfg, &cluster, &backend)?;
    println!(
        "\ndone in {} real | virtual cluster time {} | {} shuffled",
        human_duration(sw.secs()),
        human_duration(out.virtual_secs),
        human_bytes(out.shuffle_bytes)
    );
    if let Some(d) = &out.dist {
        // Measured ground truth of the distributed stage next to the
        // virtual-clock projection of the same work.
        println!(
            "distributed geodesics: {} worker(s), {} lost | {} tasks, {} retried | {} over TCP \
             | stage wall {} measured vs {} virtual projection",
            d.workers,
            d.workers_lost,
            d.tasks,
            d.retries,
            human_bytes(d.bytes_sent + d.bytes_received),
            human_duration(d.wall_secs),
            human_duration(d.virtual_secs)
        );
    }
    println!(
        "q={} blocks | graph components={} | eigen iters={} converged={}",
        out.q, out.graph_components, out.eigen_iterations, out.eigen_converged
    );
    println!("geodesics path: {}", out.geodesics.describe());
    println!("knn path: {}", out.knn.describe());
    println!("feature path: {}", out.feature.describe());
    print!("peak resident: {} cluster-wide", human_bytes(out.peak_resident_bytes));
    if out.panel_recomputes > 0 || out.panel_spill_reads > 0 {
        print!(
            " | panels: {} recomputed, {} spill re-reads",
            out.panel_recomputes, out.panel_spill_reads
        );
    }
    println!();
    println!("eigenvalues: {:?}", out.eigenvalues);
    if let Some(truth) = &ds.ground_truth {
        if truth.ncols() == cfg.d {
            println!(
                "procrustes vs ground truth: {:.6e}",
                eval::procrustes(truth, &out.embedding)
            );
        }
    }
    println!("\n{}", out.metrics_table);
    if let Some(report) = backend.offload_report() {
        println!("\noffload coverage (exact/padded artifact vs counted native fallback):");
        println!("{report}");
    }
    if let Some(path) = args.opt("out") {
        data::io::write_csv(Path::new(path), &out.embedding, None)?;
        println!("embedding written to {path}");
    }
    Ok(())
}

fn cmd_landmark(args: &Args) -> Result<()> {
    let (cfg, cluster) = parse_common(args)?;
    let backend = backend_from(args)?;
    let ds = load_dataset(args)?;
    let m: usize = args.get("landmarks", (ds.n() / 10).max(cfg.d + 1)).map_err(anyhow_str)?;
    let sw = isospark::util::Stopwatch::start();
    let out = landmark::run(&ds.points, &cfg, m, &cluster, &backend)?;
    println!(
        "L-Isomap: n={} m={} done in {} | eigenvalues {:?}",
        ds.n(),
        m,
        human_duration(sw.secs()),
        out.eigenvalues
    );
    if let Some(truth) = &ds.ground_truth {
        if truth.ncols() == cfg.d {
            println!(
                "procrustes vs ground truth: {:.6e}",
                eval::procrustes(truth, &out.embedding)
            );
        }
    }
    if let Some(path) = args.opt("out") {
        data::io::write_csv(Path::new(path), &out.embedding, None)?;
        println!("embedding written to {path}");
    }
    Ok(())
}

fn cmd_lle(args: &Args) -> Result<()> {
    let (cfg, cluster) = parse_common(args)?;
    let backend = backend_from(args)?;
    let ds = load_dataset(args)?;
    let sw = isospark::util::Stopwatch::start();
    let out = isospark::coordinator::lle::run(&ds.points, &cfg, &cluster, &backend)?;
    println!(
        "LLE: n={} done in {} | iterations={} | bottom eigenvalues {:?}",
        ds.n(),
        human_duration(sw.secs()),
        out.iterations,
        out.eigenvalues
    );
    if let Some(truth) = &ds.ground_truth {
        if truth.ncols() == cfg.d {
            let (t, c) = eval::trustworthiness_continuity(&ds.points, &out.embedding, 10, 2000);
            println!("trustworthiness={t:.3} continuity={c:.3}");
        }
    }
    if let Some(path) = args.opt("out") {
        data::io::write_csv(Path::new(path), &out.embedding, None)?;
        println!("embedding written to {path}");
    }
    Ok(())
}

fn cmd_stream(args: &Args) -> Result<()> {
    use isospark::coordinator::streaming::StreamingModel;
    let (cfg, cluster) = parse_common(args)?;
    let backend = backend_from(args)?;
    let ds = load_dataset(args)?;
    let m: usize = args.get("landmarks", (ds.n() / 8).max(cfg.d + 1)).map_err(anyhow_str)?;
    let stream_n: usize = args.get("stream-n", 256).map_err(anyhow_str)?;
    let sw = isospark::util::Stopwatch::start();
    let model = StreamingModel::fit(&ds.points, &cfg, m, &cluster, &backend)?;
    println!(
        "fitted streaming model on batch n={} with {} landmarks in {}",
        ds.n(),
        model.num_landmarks(),
        human_duration(sw.secs())
    );
    println!("{}", model.fit_report());
    let fresh = data::by_name(args.opt("dataset").unwrap_or("swiss"), stream_n, cfg.seed + 1)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let sw = isospark::util::Stopwatch::start();
    let mapped = model.map_points(&fresh.points)?;
    let per = sw.secs() / stream_n as f64;
    println!("mapped {stream_n} streamed points at {:.3} ms/point", per * 1e3);
    if let Some(truth) = &fresh.ground_truth {
        if truth.ncols() == cfg.d {
            println!("streamed procrustes vs truth: {:.6e}", eval::procrustes(truth, &mapped));
        }
    }
    if let Some(path) = args.opt("out") {
        data::io::write_csv(Path::new(path), &mapped, None)?;
        println!("streamed embedding written to {path}");
    }
    Ok(())
}

fn cmd_fit(args: &Args) -> Result<()> {
    use isospark::coordinator::streaming::StreamingModel;
    let (cfg, cluster) = parse_common(args)?;
    let backend = backend_from(args)?;
    let ds = load_dataset(args)?;
    let m: usize = args.get("landmarks", (ds.n() / 8).max(cfg.d + 1)).map_err(anyhow_str)?;
    let save = args
        .opt("save")
        .ok_or_else(|| anyhow::anyhow!("fit requires --save <dir> (the artifact directory)"))?;
    let sw = isospark::util::Stopwatch::start();
    let fit = StreamingModel::fit(&ds.points, &cfg, m, &cluster, &backend)?;
    println!(
        "fitted streaming model on batch n={} D={} with {} landmarks in {}",
        ds.n(),
        ds.dim(),
        fit.num_landmarks(),
        human_duration(sw.secs())
    );
    println!("{}", fit.fit_report());
    let model = fit.into_model();
    let dir = Path::new(save);
    model.save(dir).with_context(|| format!("save model artifact to {save}"))?;
    println!("{}", isospark::model::ModelInfo::inspect(dir)?.render());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use isospark::model::FittedModel;
    use isospark::serve::{self, registry::Registry, ServeConfig};
    // A --config [serve] section seeds the defaults; flags override it.
    let mut cfg = match args.opt("config") {
        Some(path) => RawConfig::load(Path::new(path))?.serve()?,
        None => ServeConfig { port: 8080, ..ServeConfig::default() },
    };
    if let Some(h) = args.opt("host") {
        cfg.host = h.to_string();
    }
    cfg.port = args.get("port", cfg.port).map_err(anyhow_str)?;
    cfg.threads = args.get("threads", cfg.threads).map_err(anyhow_str)?;
    cfg.threads_min = args.get("threads-min", cfg.threads_min).map_err(anyhow_str)?;
    cfg.threads_max = args.get("threads-max", cfg.threads_max).map_err(anyhow_str)?;
    cfg.max_batch = args.get("max-batch", cfg.max_batch).map_err(anyhow_str)?;
    cfg.batch_min = args.get("batch-min", cfg.batch_min).map_err(anyhow_str)?;
    cfg.target_p95_ms = args.get("target-p95-ms", cfg.target_p95_ms).map_err(anyhow_str)?;
    cfg.max_queue = args.get("max-queue", cfg.max_queue).map_err(anyhow_str)?;
    cfg.validate()?;
    // --model <dir> registers "default"; --models name=dir,... adds (or,
    // alone, provides) the named entries. The first entry is what the
    // legacy /v1/embed and /v1/reload paths alias.
    let mut entries: Vec<(String, FittedModel, Option<PathBuf>)> = Vec::new();
    if let Some(model_path) = args.opt("model") {
        let model = FittedModel::load(Path::new(model_path))
            .with_context(|| format!("load model artifact {model_path}"))?;
        entries.push(("default".to_string(), model, Some(PathBuf::from(model_path))));
    }
    if let Some(spec) = args.opt("models") {
        for part in spec.split(',').filter(|s| !s.is_empty()) {
            let (name, dir) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--models expects name=dir, got {part:?}"))?;
            let model = FittedModel::load(Path::new(dir))
                .with_context(|| format!("load model artifact {dir} for {name:?}"))?;
            entries.push((name.to_string(), model, Some(PathBuf::from(dir))));
        }
    }
    if entries.is_empty() {
        bail!("serve requires --model <dir> and/or --models name=dir[,name=dir...]");
    }
    let registry = Registry::from_entries(entries).map_err(anyhow_str)?;
    let backend = backend_from(args)?;
    let handle = serve::start_registry(registry, Some(backend), &cfg)?;
    let m = handle.model();
    println!(
        "serving {} model(s) [{}] (default: n={} D={} m={} d={} k={}) on http://{}",
        handle.registry().entries().len(),
        handle.registry().names().join(", "),
        m.n(),
        m.dim(),
        m.num_landmarks(),
        m.out_dim(),
        m.k(),
        handle.addr()
    );
    println!("  POST /v1/embed   {{\"points\": [[..], ..]}} -> {{\"embedding\": [[..], ..]}}");
    println!("  GET  /healthz    liveness + model summary");
    println!("  GET  /metrics    counters, latency histogram, batching, controllers, offload");
    println!("  POST /v1/reload  {{\"path\": \"<dir>\"}} (default: the model's source path)");
    println!("  GET  /v1/models  registered model names");
    println!("  POST /v1/models/<name>/embed | /reload, GET /v1/models/<name>/metrics");
    if let Some(pf) = args.opt("port-file") {
        std::fs::write(pf, format!("{}\n", handle.port()))
            .with_context(|| format!("write port file {pf}"))?;
    }
    handle.wait();
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    use isospark::dist::worker::{self, WorkerOptions};
    let listen = args.opt("listen").unwrap_or("127.0.0.1:0");
    let die: u64 = args.get("die-after-tasks", 0u64).map_err(anyhow_str)?;
    let opts = WorkerOptions {
        threads: args.get("threads", 0usize).map_err(anyhow_str)?,
        die_after_tasks: (die > 0).then_some(die),
    };
    worker::run_blocking(listen, opts, args.opt("port-file"))
}

fn cmd_bench_serve(args: &Args) -> Result<()> {
    use isospark::coordinator::streaming::StreamingModel;
    use isospark::serve::{self, client, ServeConfig};
    use isospark::util::json::Json;
    let (cfg, cluster) = parse_common(args)?;
    let dataset = args.opt("dataset").unwrap_or("swiss");
    let model = match args.opt("model") {
        Some(p) => isospark::model::FittedModel::load(Path::new(p))
            .with_context(|| format!("load model artifact {p}"))?,
        None => {
            let n: usize = args.get("n", 400).map_err(anyhow_str)?;
            let seed: u64 = args.get("seed", cfg.seed).map_err(anyhow_str)?;
            let ds = data::by_name(dataset, n, seed)
                .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset:?}"))?;
            let m: usize = args.get("landmarks", (n / 8).max(cfg.d + 1)).map_err(anyhow_str)?;
            let fit_cfg = IsomapConfig { block: cfg.block.min(n.max(1)), ..cfg.clone() };
            println!("no --model given: fitting an ephemeral {n}-point model (m={m})…");
            StreamingModel::fit(&ds.points, &fit_cfg, m, &cluster, &Backend::Native)?.into_model()
        }
    };
    let requests: usize = args.get("requests", 200).map_err(anyhow_str)?;
    let concurrency: usize = args.get("concurrency", 4).map_err(anyhow_str)?.max(1);
    let points: usize = args.get("points", 1).map_err(anyhow_str)?.max(1);
    let model_dim = model.dim();
    let srv_cfg = ServeConfig {
        threads: args.get("threads", 0usize).map_err(anyhow_str)?,
        threads_min: args.get("threads-min", 0usize).map_err(anyhow_str)?,
        threads_max: args.get("threads-max", 0usize).map_err(anyhow_str)?,
        max_batch: args.get("max-batch", 1024usize).map_err(anyhow_str)?,
        batch_min: args.get("batch-min", 32usize).map_err(anyhow_str)?,
        target_p95_ms: args.get("target-p95-ms", 50.0f64).map_err(anyhow_str)?,
        max_queue: args.get("max-queue", 4096usize).map_err(anyhow_str)?,
        ..ServeConfig::default()
    };
    let pool_n = (points * 4).max(256);
    let pool = data::by_name(dataset, pool_n, cfg.seed + 1)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset:?}"))?
        .points;
    anyhow::ensure!(
        pool.ncols() == model_dim,
        "query dataset D={} != model D={model_dim}; pass a matching --dataset",
        pool.ncols()
    );
    // Soak mode gates on bit-identity: the served embedding of a probe
    // batch must match in-process map_points exactly, computed *before*
    // the model moves into the server.
    let probe = pool.slice(0, points.min(pool.nrows()), 0, pool.ncols());
    let expected = if args.flag("soak") { Some(model.map_points(&probe)?) } else { None };
    let handle = serve::start(model, None, None, &srv_cfg)?;
    let addr = handle.addr();
    if let Some(expected) = expected {
        let served = client::embed(&addr, &probe)?;
        for (i, (a, b)) in expected.as_slice().iter().zip(served.as_slice()).enumerate() {
            anyhow::ensure!(
                a.to_bits() == b.to_bits(),
                "served embedding differs from in-process map_points at flat index {i}: {a} vs {b}"
            );
        }
        println!("bit-identity gate passed: served probe == in-process map_points");
        let qps: f64 = args.get("qps", 20.0f64).map_err(anyhow_str)?;
        let qps_max: f64 = args.get("qps-max", 2000.0f64).map_err(anyhow_str)?;
        let secs: f64 = args.get("soak-secs", 2.0f64).map_err(anyhow_str)?;
        println!("soak: walking QPS ladder {qps} → {qps_max} ({secs}s per step) on {addr}");
        let outcome = client::soak(&addr, "/v1/embed", qps, qps_max, secs, points, &pool)?;
        for s in &outcome.steps {
            println!(
                "  target {:>8.1} qps | achieved {:>8.1} | p95 {:>9} | shed {:>5.1}% | errors {}",
                s.target_qps,
                s.achieved_qps,
                human_duration(s.p95_us / 1e6),
                s.shed_fraction() * 100.0,
                s.errors
            );
        }
        println!(
            "knee: {:.1} qps @ p95 {} (saturated: {})",
            outcome.knee_qps,
            human_duration(outcome.knee_p95_us / 1e6),
            outcome.saturated
        );
        let mut cases: Vec<Json> = outcome.steps.iter().map(client::PacedReport::to_json).collect();
        cases.push(Json::obj(vec![
            ("name", Json::str("knee")),
            ("knee_qps", Json::num(outcome.knee_qps)),
            ("knee_p95_us", Json::num(outcome.knee_p95_us)),
            ("saturated", Json::Bool(outcome.saturated)),
        ]));
        let path = args.opt("json").unwrap_or("BENCH_serve.json");
        isospark::bench::write_kernel_section(path, "serve_soak", cases);
        println!("soak report written to {path}");
        handle.shutdown();
        return Ok(());
    }
    println!(
        "loopback server on {addr} | {concurrency} client(s) × {} request(s) × {points} point(s)",
        requests.div_ceil(concurrency)
    );
    let report =
        client::loopback_load(&addr, concurrency, requests.div_ceil(concurrency), points, &pool)?;
    let rows = vec![
        vec!["requests".to_string(), report.requests.to_string()],
        vec!["wall".to_string(), human_duration(report.wall_secs)],
        vec!["QPS".to_string(), format!("{:.1}", report.qps)],
        vec!["p50".to_string(), human_duration(report.p50_us / 1e6)],
        vec!["p95".to_string(), human_duration(report.p95_us / 1e6)],
        vec!["p99".to_string(), human_duration(report.p99_us / 1e6)],
        vec!["mean".to_string(), human_duration(report.mean_us / 1e6)],
        vec!["max".to_string(), human_duration(report.max_us / 1e6)],
    ];
    println!("{}", render_table(&rows));
    // Server-side view: how well did micro-batching coalesce the load?
    let (_, metrics) = client::get_json(&addr, "/metrics")?;
    if let Some(b) = metrics.get("batching") {
        let g = |k: &str| b.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        println!(
            "micro-batching: {} batches over {} points (mean {:.1}, max {} pts/batch)",
            g("batches"),
            g("points"),
            g("mean_points_per_batch"),
            g("max_points_in_batch")
        );
    }
    if let Some(path) = args.opt("json") {
        let out = Json::obj(vec![(
            "cases",
            Json::arr(vec![report.to_json("bench-serve", concurrency, points)]),
        )]);
        std::fs::write(path, out.to_string()).with_context(|| format!("write {path}"))?;
        println!("report written to {path}");
    }
    handle.shutdown();
    Ok(())
}

fn cost_model(args: &Args) -> CostModel {
    if args.flag("calibrate") {
        eprintln!("calibrating cost model from native kernels…");
        CostModel::calibrate(256)
    } else {
        CostModel::paper_like()
    }
}

fn parse_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|x| {
            x.trim().parse::<usize>().map_err(|e| anyhow::anyhow!("bad list entry {x:?}: {e}"))
        })
        .collect()
}

fn cmd_scale_table(args: &Args) -> Result<()> {
    let b: usize = args.get("block", 1500).map_err(anyhow_str)?;
    let nodes_list = parse_list(args.opt("nodes-list").unwrap_or("2,4,8,12,16,20,24"))?;
    let model = cost_model(args);
    let suite = Workload::paper_suite(b);
    println!("== Table I: execution time (virtual minutes), b={b} ==");
    let mut time_rows = vec![header_row(&nodes_list)];
    let mut results: Vec<Vec<Option<f64>>> = Vec::new();
    for w in &suite {
        let mut row = vec![w.name.clone()];
        let mut per: Vec<Option<f64>> = Vec::new();
        for &p in &nodes_list {
            let proj = sim::project(w, &ClusterConfig::paper_testbed(p), &model);
            per.push(proj.total_secs);
            row.push(match proj.total_secs {
                Some(s) => format!("{:.2}", s / 60.0),
                None => "-".to_string(),
            });
        }
        results.push(per);
        time_rows.push(row);
    }
    println!("{}", render_table(&time_rows));

    println!("== Table II: relative speedup S_p = T_min / T_p ==");
    let mut sp_rows = vec![header_row(&nodes_list)];
    for (w, per) in suite.iter().zip(&results) {
        // T_min = time on the smallest feasible node count.
        let t_base = per.iter().flatten().next().cloned();
        let mut row = vec![w.name.clone()];
        for v in per {
            row.push(match (t_base, v) {
                (Some(b), Some(t)) => format!("{:.2}", b / t),
                _ => "-".to_string(),
            });
        }
        sp_rows.push(row);
    }
    println!("{}", render_table(&sp_rows));

    println!("== Table III: relative efficiency E_p = S_p·p_min/p ==");
    let mut ef_rows = vec![header_row(&nodes_list)];
    for (w, per) in suite.iter().zip(&results) {
        let base = per.iter().zip(&nodes_list).find_map(|(v, &p)| v.map(|t| (t, p)));
        let mut row = vec![w.name.clone()];
        for (v, &p) in per.iter().zip(&nodes_list) {
            row.push(match (base, v) {
                (Some((tb, pb)), Some(t)) => format!("{:.2}", (tb / t) * pb as f64 / p as f64),
                _ => "-".to_string(),
            });
        }
        ef_rows.push(row);
    }
    println!("{}", render_table(&ef_rows));
    Ok(())
}

fn header_row(nodes: &[usize]) -> Vec<String> {
    let mut h = vec!["Name".to_string()];
    h.extend(nodes.iter().map(|p| p.to_string()));
    h
}

fn cmd_blocksize(args: &Args) -> Result<()> {
    let n: usize = args.get("n", 75_000).map_err(anyhow_str)?;
    let dim: usize = args.get("dim", 3).map_err(anyhow_str)?;
    let nodes: usize = args.get("nodes", 24).map_err(anyhow_str)?;
    let blocks =
        parse_list(args.opt("blocks").unwrap_or("500,750,1000,1500,2000,2500,3000,4000"))?;
    let model = cost_model(args);
    println!("== Fig. 6: block-size sweep, n={n} D={dim} on {nodes} nodes ==");
    let mut rows = vec![vec![
        "b".to_string(),
        "q".to_string(),
        "time".to_string(),
        "apsp".to_string(),
        "knn".to_string(),
    ]];
    for b in blocks {
        let w = Workload::new("sweep", n, dim, b);
        let proj = sim::project(&w, &ClusterConfig::paper_testbed(nodes), &model);
        rows.push(vec![
            b.to_string(),
            n.div_ceil(b).to_string(),
            proj.total_secs.map_or("-".into(), |s| format!("{:.2} min", s / 60.0)),
            format!("{:.2} min", proj.apsp_secs / 60.0),
            format!("{:.2} min", proj.knn_secs / 60.0),
        ]);
    }
    println!("{}", render_table(&rows));
    Ok(())
}

fn cmd_emnist(args: &Args) -> Result<()> {
    let (mut cfg, cluster) = parse_common(args)?;
    let backend = backend_from(args)?;
    let n: usize = args.get("n", 512).map_err(anyhow_str)?;
    cfg.d = args.get("d", 2).map_err(anyhow_str)?;
    let ds = data::emnist_synth::generate(n, cfg.seed);
    println!("synthetic EMNIST: n={n} D={}", ds.dim());
    let out = isomap::run_with(&ds.points, &cfg, &cluster, &backend)?;
    let labels = ds.labels.as_ref().unwrap();
    let truth = ds.ground_truth.as_ref().unwrap();

    // Fig. 5 analysis: correlate embedding axes with latent factors.
    let corr = |a: &[f64], b: &[f64]| -> f64 {
        let n = a.len() as f64;
        let (ma, mb) = (a.iter().sum::<f64>() / n, b.iter().sum::<f64>() / n);
        let mut cov = 0.0;
        let mut va = 0.0;
        let mut vb = 0.0;
        for (x, y) in a.iter().zip(b) {
            cov += (x - ma) * (y - mb);
            va += (x - ma) * (x - ma);
            vb += (y - mb) * (y - mb);
        }
        cov / (va * vb).sqrt()
    };
    for axis in 0..cfg.d.min(2) {
        let emb: Vec<f64> = (0..n).map(|i| out.embedding[(i, axis)]).collect();
        let curv: Vec<f64> = (0..n).map(|i| truth[(i, 0)]).collect();
        let slant: Vec<f64> = (0..n).map(|i| truth[(i, 1)]).collect();
        println!(
            "D{}: corr(curvature)={:+.3} corr(slant)={:+.3}",
            axis + 1,
            corr(&emb, &curv),
            corr(&emb, &slant)
        );
    }
    // Per-digit centroids (the clusters of Fig. 5a).
    let mut rows =
        vec![vec!["digit".into(), "count".into(), "centroid D1".into(), "centroid D2".into()]];
    for digit in 0..10usize {
        let idx: Vec<usize> = (0..n).filter(|&i| labels[i] == digit).collect();
        if idx.is_empty() {
            continue;
        }
        let c: Vec<f64> = (0..cfg.d.min(2))
            .map(|j| idx.iter().map(|&i| out.embedding[(i, j)]).sum::<f64>() / idx.len() as f64)
            .collect();
        rows.push(vec![
            digit.to_string(),
            idx.len().to_string(),
            format!("{:+.3}", c[0]),
            format!("{:+.3}", c.get(1).copied().unwrap_or(0.0)),
        ]);
    }
    println!("{}", render_table(&rows));
    if let Some(path) = args.opt("out") {
        data::io::write_csv(Path::new(path), &out.embedding, None)?;
        println!("embedding written to {path}");
    }
    Ok(())
}

/// Push one ragged (`b ∤ n`) call of every block op through the backend so
/// `info` can demonstrate the padded-execution path and render live
/// offload counters: each op lands as an exact hit, a padded hit, or a
/// counted native fallback.
fn offload_smoke(backend: &Backend) {
    use isospark::linalg::Matrix;
    let fill = |r: usize, c: usize, s: f64| {
        let mut m = Matrix::zeros(r, c);
        for i in 0..r {
            for j in 0..c {
                m[(i, j)] = ((i * c + j) as f64 * 0.37 + s).sin().abs() + 0.1;
            }
        }
        m
    };
    let x = fill(5, 3, 0.0);
    let _ = backend.dist_block(&x, &fill(7, 3, 1.0));
    let a = fill(5, 5, 2.0);
    let mut dst = Matrix::full(5, 5, f64::INFINITY);
    backend.minplus_into(&a, &fill(5, 5, 3.0), &mut dst);
    let mut g = fill(5, 5, 4.0);
    backend.fw_inplace(&mut g);
    let mut blk = fill(5, 5, 5.0);
    let mu: Vec<f64> = (0..5).map(|i| i as f64 * 0.2).collect();
    backend.center_block(&mut blk, &mu, &mu, 0.5);
    let mut out = Matrix::zeros(5, 2);
    backend.gemm_acc(&a, &fill(5, 2, 6.0), &mut out);
    let mut out_t = Matrix::zeros(5, 2);
    backend.gemm_t_acc(&a, &fill(5, 2, 7.0), &mut out_t);
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("isospark {} — three-layer Rust + JAX + Pallas Isomap", env!("CARGO_PKG_VERSION"));
    if let Some(mp) = args.opt("model") {
        // Manifest-only inspection: dims, landmark count, format version,
        // and per-file size health — works on artifacts too broken to
        // load, which is the whole point of inspecting one.
        let info = isospark::model::ModelInfo::inspect(Path::new(mp))
            .with_context(|| format!("inspect model artifact {mp}"))?;
        println!("{}", info.render());
        return Ok(());
    }
    let dir = PathBuf::from(args.opt("artifacts").unwrap_or("artifacts"));
    match isospark::runtime::PjrtEngine::load(&dir) {
        Ok(rt) => {
            println!("artifacts ({}):", dir.display());
            for line in rt.inventory() {
                println!("  {line}");
            }
            // Ragged-shape smoke (opt-in: it compiles one executable per
            // op, which costs seconds): exercises the shape-polymorphic
            // padded path on every op and shows the coverage counters.
            // Hard artifact errors (the fallback policy panics on them so
            // pipelines never silently degrade) are *reported* here —
            // `info` is the command for inspecting a broken artifact set,
            // so it must survive one.
            if args.flag("smoke") {
                let backend = Backend::Pjrt(std::sync::Arc::new(rt));
                let smoke = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    offload_smoke(&backend)
                }));
                println!("\nragged-block (b=5) offload smoke:");
                if smoke.is_err() {
                    println!("  artifact set is broken — a block op failed hard (see above)");
                }
                if let Some(report) = backend.offload_report() {
                    println!("{report}");
                }
            } else {
                println!("(run `isospark info --smoke` for a ragged-block offload check)");
            }
        }
        Err(e) => println!("no artifacts loaded: {e:#}"),
    }
    let cl = ClusterConfig::paper_testbed(25);
    println!(
        "\npaper testbed model: {} nodes × {} cores, {}/node, GbE {:.0} MB/s, disk {:.0} MB/s",
        cl.nodes,
        cl.cores_per_node,
        human_bytes(cl.mem_per_node),
        cl.net_bandwidth / 1e6,
        cl.disk_bandwidth / 1e6
    );
    Ok(())
}
