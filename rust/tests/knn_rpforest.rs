//! rp-forest kNN front end: the ISSUE-6 acceptance suite.
//!
//! * recall ≥ 0.95 @ k = 10 against exact lists on swiss-roll n = 2048;
//! * bit-determinism for any worker count [1, 2, 4, 8];
//! * the fully sub-quadratic pipeline (`--knn rp-forest --geodesics
//!   sparse-dijkstra`) bit-identical across runs and pool sizes;
//! * config parse/reject for the new keys;
//! * graceful errors for degenerate tree count / leaf size.

use isospark::backend::Backend;
use isospark::baselines;
use isospark::config::{ClusterConfig, GeodesicsMode, IsomapConfig, KnnMode, RawConfig};
use isospark::coordinator::{isomap, knn};
use isospark::data::swiss_roll;
use isospark::engine::SparkContext;
use isospark::eval;
use isospark::knn_approx::{knn_lists, RpForestParams};
use isospark::linalg::Matrix;

fn cluster(threads: usize) -> ClusterConfig {
    ClusterConfig { parallelism: threads, cores_per_node: 4, ..ClusterConfig::local() }
}

fn assert_bits_equal(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.nrows(), a.ncols()), (b.nrows(), b.ncols()), "{what}: shape");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i} differs: {x} vs {y}");
    }
}

#[test]
fn recall_at_least_095_on_swiss_roll_2048() {
    // The headline acceptance bar, at the defaults the pipeline ships
    // with (T = 8, leaf = max(4k, 32) = 40 at k = 10).
    let ds = swiss_roll::euler_isometric(2048, 11);
    let cfg = IsomapConfig { knn: KnnMode::RpForest, ..Default::default() };
    let params = RpForestParams {
        trees: cfg.rp_trees,
        leaf_size: cfg.rp_leaf_resolved(),
        seed: cfg.seed,
    };
    let (lists, stats) = knn_lists(&ds.points, 10, &params, 0).unwrap();
    let exact = baselines::brute_knn(&ds.points, 10);
    let recall = eval::recall_at_k(&lists, &exact, 10);
    assert!(recall >= 0.95, "recall@10 = {recall} (acceptance bar is 0.95)");
    // Sub-quadratic candidate generation: far fewer pairs than n(n−1)/2.
    let n = 2048u64;
    assert!(
        stats.candidate_pairs < n * n / 5,
        "candidate pairs {} ≥ 20% of n²",
        stats.candidate_pairs
    );
}

#[test]
fn lists_bit_deterministic_across_worker_counts() {
    let ds = swiss_roll::euler_isometric(1500, 31);
    let params = RpForestParams { trees: 8, leaf_size: 40, seed: 42 };
    let (reference, ref_stats) = knn_lists(&ds.points, 10, &params, 1).unwrap();
    for workers in [2, 4, 8] {
        let (lists, stats) = knn_lists(&ds.points, 10, &params, workers).unwrap();
        assert_eq!(
            stats.candidate_pairs, ref_stats.candidate_pairs,
            "workers={workers}: pair count drifted"
        );
        for (i, (a, b)) in reference.iter().zip(&lists).enumerate() {
            assert_eq!(a.len(), b.len(), "workers={workers} point {i}: length");
            for ((da, ja), (db, jb)) in a.iter().zip(b) {
                assert_eq!(ja, jb, "workers={workers} point {i}: neighbor id");
                assert_eq!(
                    da.to_bits(),
                    db.to_bits(),
                    "workers={workers} point {i}: distance bits"
                );
            }
        }
    }
}

#[test]
fn full_subquadratic_pipeline_bit_identical() {
    // rp-forest candidates + sparse Dijkstra geodesics: the embedding and
    // spectrum must be bit-identical across repeated runs and across
    // worker-pool sizes — the whole pipeline honors the determinism
    // contract, not just the lists.
    let ds = swiss_roll::euler_isometric(500, 7);
    let cfg = IsomapConfig {
        k: 10,
        d: 2,
        block: 64,
        knn: KnnMode::RpForest,
        geodesics: GeodesicsMode::SparseDijkstra,
        ..Default::default()
    };
    let run = |threads: usize| isomap::run(&ds.points, &cfg, &cluster(threads)).unwrap();
    let reference = run(1);
    assert!(matches!(reference.knn, knn::KnnPath::RpForest(_)));
    let repeat = run(1);
    assert_bits_equal(&reference.embedding, &repeat.embedding, "repeat run");
    for threads in [2, 4, 8] {
        let out = run(threads);
        assert_bits_equal(&reference.embedding, &out.embedding, "threads");
        for (a, b) in reference.eigenvalues.iter().zip(&out.eigenvalues) {
            assert_eq!(a.to_bits(), b.to_bits(), "eigenvalue bits at threads={threads}");
        }
    }
}

#[test]
fn build_lists_fork_selects_the_forest() {
    let ds = swiss_roll::euler_isometric(400, 3);
    let base = IsomapConfig { k: 8, block: 64, ..Default::default() };
    let ctx = SparkContext::new(ClusterConfig::local());
    let exact = knn::build_lists(&ctx, &ds.points, &base, &Backend::Native).unwrap();
    assert!(matches!(exact.path, knn::KnnPath::Exact));
    let rp_cfg = IsomapConfig { knn: KnnMode::RpForest, ..base };
    let ctx = SparkContext::new(ClusterConfig::local());
    let rp = knn::build_lists(&ctx, &ds.points, &rp_cfg, &Backend::Native).unwrap();
    let knn::KnnPath::RpForest(stats) = &rp.path else {
        panic!("expected rp-forest path, got {}", rp.path.describe())
    };
    assert!(stats.pair_fraction() < 0.5);
    assert_eq!(rp.q, exact.q);
    // High agreement between the two front ends at these settings.
    let recall = eval::recall_at_k(&rp.lists, &exact.lists, 8);
    assert!(recall >= 0.95, "recall@8 = {recall}");
}

#[test]
fn config_keys_parse_and_reject() {
    let raw = RawConfig::parse(
        "[isomap]\nknn = rp-forest\nrp_trees = 6\nrp_leaf = 48\ngeodesics = sparse-dijkstra\n",
    )
    .unwrap();
    let cfg = raw.isomap().unwrap();
    assert_eq!(cfg.knn, KnnMode::RpForest);
    assert_eq!(cfg.rp_trees, 6);
    assert_eq!(cfg.rp_leaf, 48);
    assert_eq!(cfg.rp_leaf_resolved(), 48);
    assert!(cfg.validate(1000).is_ok());

    // Unknown spelling is rejected at parse time…
    assert!(RawConfig::parse("[isomap]\nknn = annoy\n").unwrap().isomap().is_err());
    // …and non-numeric knob values too.
    assert!(RawConfig::parse("[isomap]\nrp_trees = many\n").unwrap().isomap().is_err());
    // The default config never selects the forest.
    assert_eq!(IsomapConfig::default().knn, KnnMode::Exact);
}

#[test]
fn degenerate_forest_shapes_error_gracefully() {
    let ds = swiss_roll::euler_isometric(128, 5);

    // Zero trees: rejected by config validation and by the forest itself.
    let cfg = IsomapConfig { knn: KnnMode::RpForest, rp_trees: 0, ..Default::default() };
    let err = cfg.validate(128).unwrap_err();
    assert!(format!("{err:#}").contains("rp_trees"), "{err:#}");
    let err = knn_lists(&ds.points, 10, &RpForestParams { trees: 0, leaf_size: 64, seed: 1 }, 1)
        .unwrap_err();
    assert!(format!("{err:#}").contains("≥ 1"), "{err:#}");

    // Leaf too small to hold k candidates: rejected with the constraint
    // spelled out, end to end through the pipeline entry point.
    let cfg = IsomapConfig { knn: KnnMode::RpForest, rp_leaf: 4, ..Default::default() };
    let err = isomap::run(&ds.points, &cfg, &ClusterConfig::local()).unwrap_err();
    assert!(format!("{err:#}").contains("must exceed k"), "{err:#}");
}
