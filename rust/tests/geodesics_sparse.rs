//! Sparse-geodesics subsystem, end to end: CSR construction from real kNN
//! lists, pooled-vs-serial Dijkstra bit-equality, sparse-vs-dense
//! geodesic agreement on swiss-roll (the acceptance bound: 1e-9
//! elementwise at n ≤ 512, k = 10), and full-pipeline determinism of the
//! `--geodesics sparse-dijkstra` mode for any worker count.

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, GeodesicsMode, IsomapConfig};
use isospark::coordinator::{apsp, dense_from_blocks, isomap, knn};
use isospark::data::swiss_roll;
use isospark::engine::SparkContext;
use isospark::graph::{dijkstra, CsrGraph};
use isospark::linalg::Matrix;

fn knn_lists(n: usize, k: usize, b: usize, seed: u64) -> (Matrix, Vec<Vec<(f64, usize)>>) {
    let ds = swiss_roll::euler_isometric(n, seed);
    let ctx = SparkContext::new(ClusterConfig::local());
    let cfg = IsomapConfig { k, block: b, ..Default::default() };
    let kl = knn::build_lists(&ctx, &ds.points, &cfg, &Backend::Native).unwrap();
    (ds.points, kl.lists)
}

#[test]
fn csr_from_real_knn_lists_is_symmetric_and_sorted() {
    // Real kNN lists are ragged in the graph sense: mutual neighbors
    // produce duplicate arcs, non-mutual ones produce single directed
    // edges — the CSR must come out symmetric, deduplicated, sorted.
    let (_, lists) = knn_lists(200, 10, 64, 3);
    let g = CsrGraph::from_knn_lists(&lists).unwrap();
    assert_eq!(g.n(), 200);
    let undirected: usize = lists.iter().map(Vec::len).sum();
    // Symmetrization can only dedup, never add: directed arc count is at
    // most twice the list entries and at least the list entries.
    assert!(g.num_edges() <= 2 * undirected && g.num_edges() >= undirected);
    for u in 0..g.n() {
        let (cols, weights) = g.neighbors(u);
        for w in cols.windows(2) {
            assert!(w[0] < w[1], "row {u} not strictly column-sorted");
        }
        for (&v, &w) in cols.iter().zip(weights) {
            let (vc, vw) = g.neighbors(v as usize);
            let pos = vc.binary_search(&(u as u32)).expect("missing reverse arc");
            assert_eq!(vw[pos].to_bits(), w.to_bits(), "asymmetric weight {u}<->{v}");
        }
    }
    assert_eq!(g.components(), 1);
    assert!(g.require_connected().is_ok());
}

#[test]
fn pooled_dijkstra_bit_equal_for_any_worker_count() {
    let (_, lists) = knn_lists(300, 10, 64, 5);
    let g = CsrGraph::from_knn_lists(&lists).unwrap();
    let sources: Vec<usize> = (0..300).step_by(7).collect();
    let serial = dijkstra::multi_source(&g, &sources, 1);
    for workers in [2, 3, 4, 8, 16] {
        let pooled = dijkstra::multi_source(&g, &sources, workers);
        for (i, (a, b)) in serial.as_slice().iter().zip(pooled.as_slice()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "workers={workers} flat index {i}");
        }
    }
}

#[test]
fn sparse_agrees_with_dense_fw_at_acceptance_scale() {
    // Acceptance bound: swiss-roll, n ≤ 512, k = 10, agreement within
    // 1e-9 elementwise on the geodesic distances.
    let n = 512;
    let (b, k) = (128, 10);
    let ds = swiss_roll::euler_isometric(n, 13);
    let cfg = IsomapConfig { k, block: b, ..Default::default() };

    let ctx_dense = SparkContext::new(ClusterConfig::local());
    let kg = knn::build(&ctx_dense, &ds.points, &cfg, &Backend::Native).unwrap();
    let a_dense = apsp::solve(kg.graph, kg.q, &cfg, &Backend::Native).unwrap();
    let dense = dense_from_blocks(&a_dense, n, b).map(|v| v.sqrt());

    let ctx_sparse = SparkContext::new(ClusterConfig::local());
    let a_sparse = apsp::solve_sparse(&ctx_sparse, &kg.lists, n, &cfg).unwrap();
    let sparse = dense_from_blocks(&a_sparse, n, b).map(|v| v.sqrt());

    for i in 0..n {
        for j in 0..n {
            let (x, y) = (dense[(i, j)], sparse[(i, j)]);
            assert!((x - y).abs() <= 1e-9, "({i},{j}): dense {x} vs sparse {y}");
        }
    }
}

#[test]
fn sparse_pipeline_bit_deterministic_across_pool_sizes() {
    // The tentpole guarantee end to end: the whole sparse-mode pipeline
    // (kNN -> CSR Dijkstra -> centering -> eigen) is bit-identical for
    // any physical worker count.
    let ds = swiss_roll::euler_isometric(150, 23);
    let cfg = IsomapConfig {
        k: 8,
        d: 2,
        block: 32,
        geodesics: GeodesicsMode::SparseDijkstra,
        ..Default::default()
    };
    let run_with_threads = |threads: usize| {
        let cluster = ClusterConfig { parallelism: threads, ..ClusterConfig::local() };
        isomap::run(&ds.points, &cfg, &cluster).unwrap()
    };
    let seq = run_with_threads(1);
    assert_eq!(seq.geodesics, GeodesicsMode::SparseDijkstra);
    for threads in [2, 4, 8] {
        let par = run_with_threads(threads);
        for (a, b) in seq.embedding.as_slice().iter().zip(par.embedding.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
        }
    }
}

#[test]
fn disconnected_graph_bails_with_context_before_any_panel() {
    // Two severed halves: drop every cross-half edge from a real kNN run.
    let (_, mut lists) = knn_lists(80, 6, 32, 7);
    for (i, list) in lists.iter_mut().enumerate() {
        list.retain(|&(_, j)| (i < 40) == (j < 40));
    }
    let g = CsrGraph::from_knn_lists(&lists).unwrap();
    assert!(g.components() >= 2);
    let ctx = SparkContext::new(ClusterConfig::local());
    let cfg = IsomapConfig { k: 6, block: 32, ..Default::default() };
    let err = apsp::solve_sparse(&ctx, &lists, 80, &cfg).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("disconnected") && msg.contains("increase k"), "{msg}");
}

#[test]
fn ragged_last_block_and_single_block() {
    // b ∤ n exercises the ragged tail panel; b ≥ n collapses to one panel.
    for (n, b) in [(70usize, 32usize), (40, 64)] {
        let ds = swiss_roll::euler_isometric(n, 19);
        let cfg = IsomapConfig { k: 8, block: b, ..Default::default() };
        let ctx = SparkContext::new(ClusterConfig::local());
        let kg = knn::build(&ctx, &ds.points, &cfg, &Backend::Native).unwrap();
        let ctx2 = SparkContext::new(ClusterConfig::local());
        let a_sparse = apsp::solve_sparse(&ctx2, &kg.lists, n, &cfg).unwrap();
        let sparse = dense_from_blocks(&a_sparse, n, b).map(|v| v.sqrt());
        let a_dense = apsp::solve(kg.graph, kg.q, &cfg, &Backend::Native).unwrap();
        let dense = dense_from_blocks(&a_dense, n, b).map(|v| v.sqrt());
        for i in 0..n {
            for j in 0..n {
                let (x, y) = (dense[(i, j)], sparse[(i, j)]);
                assert!((x - y).abs() <= 1e-9, "n={n} b={b} ({i},{j}): {x} vs {y}");
            }
        }
    }
}
