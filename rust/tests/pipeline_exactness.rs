//! Pipeline exactness: the distributed implementation must be *exact*
//! Isomap (the paper's headline property), i.e. bit-comparable to the
//! dense single-node textbook pipeline at every stage, for every block
//! size, ragged or not, on every simulated cluster size.

use isospark::backend::Backend;
use isospark::baselines;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::{apsp, centering, dense_from_blocks, isomap, knn, num_blocks};
use isospark::data::{clusters, emnist_synth, swiss_roll};
use isospark::engine::SparkContext;
use isospark::eval::procrustes;
use isospark::kernels::centering::center_full_direct;

fn geodesics_via_engine(
    x: &isospark::linalg::Matrix,
    k: usize,
    b: usize,
    cluster: &ClusterConfig,
) -> isospark::linalg::Matrix {
    let ctx = SparkContext::new(cluster.clone());
    let cfg = IsomapConfig { k, block: b, ..Default::default() };
    let be = Backend::Native;
    let kg = knn::build(&ctx, x, &cfg, &be).unwrap();
    let a = apsp::solve(kg.graph, kg.q, &cfg, &be).unwrap();
    dense_from_blocks(&a, x.nrows(), b).map(|v| v.sqrt())
}

#[test]
fn geodesics_exact_across_block_sizes() {
    let ds = swiss_roll::euler_isometric(96, 1);
    let want = {
        let g = baselines::knn_graph_dense(&baselines::brute_knn(&ds.points, 8));
        baselines::dijkstra_apsp(&g)
    };
    for b in [16usize, 24, 32, 96] {
        let got = geodesics_via_engine(&ds.points, 8, b, &ClusterConfig::local());
        assert!(got.max_abs_diff(&want) < 1e-9, "b={b}");
    }
}

#[test]
fn geodesics_exact_on_multinode_cluster() {
    // Simulated topology must not alter numerics.
    let ds = swiss_roll::euler_isometric(80, 2);
    let a = geodesics_via_engine(&ds.points, 8, 16, &ClusterConfig::local());
    let b = geodesics_via_engine(&ds.points, 8, 16, &ClusterConfig::paper_testbed(8));
    assert_eq!(a.as_slice(), b.as_slice());
}

#[test]
fn centered_matrix_matches_dense_formula() {
    let ds = swiss_roll::euler_isometric(64, 3);
    let ctx = SparkContext::new(ClusterConfig::local());
    let cfg = IsomapConfig { k: 8, block: 16, ..Default::default() };
    let be = Backend::Native;
    let kg = knn::build(&ctx, &ds.points, &cfg, &be).unwrap();
    let a = apsp::solve(kg.graph, kg.q, &cfg, &be).unwrap();
    let dense_a = dense_from_blocks(&a, 64, 16);
    let (centered, _) = centering::center(a, 64, 16, &be).unwrap();
    let got = dense_from_blocks(&centered, 64, 16);
    let mut want = dense_a;
    center_full_direct(&mut want);
    assert!(got.max_abs_diff(&want) < 1e-9);
}

#[test]
fn full_pipeline_equals_dense_reference_various_datasets() {
    for (name, x, k) in [
        ("swiss", swiss_roll::euler_isometric(72, 4).points, 8),
        ("scurve", swiss_roll::s_curve(72, 5).points, 8),
        ("clusters", clusters::gaussian_clusters(72, 6, 2, 0.8, 6).points, 12),
    ] {
        let cfg = IsomapConfig { k, d: 2, block: 24, ..Default::default() };
        let out = match isomap::run(&x, &cfg, &ClusterConfig::local()) {
            Ok(o) => o,
            Err(e) => panic!("{name}: {e:#}"),
        };
        if out.graph_components != 1 {
            continue; // disconnected config not comparable
        }
        let reference = baselines::reference_isomap(&x, k, 2);
        let err = procrustes(&reference.embedding, &out.embedding);
        assert!(err < 1e-7, "{name}: procrustes vs dense reference = {err}");
    }
}

#[test]
fn emnist_synth_pipeline_runs_end_to_end() {
    let ds = emnist_synth::generate(128, 8);
    let cfg = IsomapConfig { k: 10, d: 2, block: 32, ..Default::default() };
    let out = isomap::run(&ds.points, &cfg, &ClusterConfig::local()).unwrap();
    assert_eq!(out.embedding.nrows(), 128);
    assert!(out.embedding.as_slice().iter().all(|v| v.is_finite()));
}

#[test]
fn eigenvalue_scaling_matches_alg1() {
    // Y columns must have norm sqrt(λ_i)·‖q_i‖ = sqrt(λ_i).
    let ds = swiss_roll::euler_isometric(100, 9);
    let cfg = IsomapConfig { k: 10, d: 2, block: 32, ..Default::default() };
    let out = isomap::run(&ds.points, &cfg, &ClusterConfig::local()).unwrap();
    for j in 0..2 {
        let norm2: f64 = (0..100).map(|i| out.embedding[(i, j)].powi(2)).sum();
        assert!(
            (norm2 - out.eigenvalues[j]).abs() / out.eigenvalues[j] < 1e-6,
            "column {j}: ‖y‖²={norm2} λ={}",
            out.eigenvalues[j]
        );
    }
}

#[test]
fn pipeline_deterministic() {
    let ds = swiss_roll::euler_isometric(60, 10);
    let cfg = IsomapConfig { k: 8, d: 2, block: 16, ..Default::default() };
    let a = isomap::run(&ds.points, &cfg, &ClusterConfig::local()).unwrap();
    let b = isomap::run(&ds.points, &cfg, &ClusterConfig::local()).unwrap();
    assert_eq!(a.embedding.as_slice(), b.embedding.as_slice());
    assert_eq!(a.eigen_iterations, b.eigen_iterations);
}
