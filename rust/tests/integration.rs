//! Cross-module integration: CLI binary smoke tests, config-file loading,
//! simulator-vs-engine validation, partitioner regimes, and L-Isomap vs
//! exact Isomap — everything that spans more than one subsystem.

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, IsomapConfig, RawConfig};
use isospark::coordinator::{apsp, blocks_from_dense, isomap, num_blocks};
use isospark::data::swiss_roll;
use isospark::engine::partitioner::{GridPartitioner, HashPartitioner, UpperTriangularPartitioner};
use isospark::engine::{Partitioner, SparkContext};
use isospark::linalg::Matrix;
use isospark::sim::{self, CostModel, Workload};
use isospark::util::Rng;
use std::sync::Arc;

#[test]
fn config_file_roundtrip() {
    let dir = std::env::temp_dir().join("isospark_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cluster.toml");
    std::fs::write(
        &path,
        "[isomap]\nk = 12\nblock = 64\ncheckpoint_every = 5\n[cluster]\nnodes = 6\ncores_per_node = 2\n",
    )
    .unwrap();
    let raw = RawConfig::load(&path).unwrap();
    let iso = raw.isomap().unwrap();
    let cl = raw.cluster().unwrap();
    assert_eq!((iso.k, iso.block, iso.checkpoint_every), (12, 64, 5));
    assert_eq!((cl.nodes, cl.cores_per_node), (6, 2));
    // And the loaded config actually drives a run.
    let ds = swiss_roll::euler_isometric(96, 1);
    let out = isomap::run(&ds.points, &iso, &cl).unwrap();
    assert_eq!(out.embedding.ncols(), iso.d);
}

#[test]
fn projection_tracks_engine_within_2x() {
    // The paper-scale simulator must agree with the real engine's virtual
    // clock at a size both can run.
    let n = 512;
    let b = 128;
    let ds = swiss_roll::euler_isometric(n, 3);
    let cfg = IsomapConfig { k: 10, d: 2, block: b, ..Default::default() };
    let cluster = ClusterConfig::paper_testbed(4);
    let out = isomap::run(&ds.points, &cfg, &cluster).unwrap();
    let w = Workload { eigen_iters: out.eigen_iterations, ..Workload::new("v", n, 3, b) };
    let proj = sim::project(&w, &cluster, &CostModel::calibrate(b));
    let ratio = out.virtual_secs / proj.total_secs.unwrap();
    assert!(
        (0.4..2.5).contains(&ratio),
        "projection off by {ratio}x (engine {} vs projected {:?})",
        out.virtual_secs,
        proj.total_secs
    );
}

#[test]
fn partitioner_regimes_ut_beats_hash() {
    // In the paper's packing regime (B blocks per partition), the custom
    // partitioner's shuffle volume beats the Spark-default hash. (MLlib's
    // grid is given UT storage here it cannot actually express — see
    // benches/ablation_partitioner.rs for the full discussion.)
    fn ring(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed(seed);
        let mut g = Matrix::full(n, n, f64::INFINITY);
        for i in 0..n {
            g[(i, i)] = 0.0;
            let j = (i + 1) % n;
            let w = rng.range(0.1, 1.0);
            g[(i, j)] = w;
            g[(j, i)] = w;
        }
        g
    }
    let n = 768;
    let b = 64;
    let q = num_blocks(n, b);
    let parts = q * (q + 1) / 2 / 4;
    let g = ring(n, 1);
    let cfg = IsomapConfig { block: b, ..Default::default() };
    let shuffle = |part: Arc<dyn Partitioner>| -> u64 {
        let ctx = SparkContext::new(ClusterConfig::paper_testbed(4));
        let rdd = ctx.parallelize("g", blocks_from_dense(&g, b), part);
        let _ = apsp::solve(rdd, q, &cfg, &Backend::Native).unwrap();
        ctx.total_shuffle_bytes()
    };
    let ut = shuffle(Arc::new(UpperTriangularPartitioner::new(q, parts)));
    let hash = shuffle(Arc::new(HashPartitioner::new(parts)));
    let grid = shuffle(Arc::new(GridPartitioner::new(q, parts)));
    assert!(ut < hash, "ut={ut} hash={hash}");
    // All three complete with identical numerics (checked elsewhere); here
    // just sanity that grid is in the same order of magnitude.
    assert!(grid < 2 * hash);
}

#[test]
fn landmark_speed_quality_tradeoff() {
    // L-Isomap must be cheaper than exact Isomap (it skips the O(n³) APSP)
    // and still structurally agree with it.
    use isospark::coordinator::landmark;
    let ds = swiss_roll::euler_isometric(512, 7);
    let cfg = IsomapConfig { k: 10, d: 2, block: 128, ..Default::default() };
    let sw = isospark::util::Stopwatch::start();
    let exact = isomap::run(&ds.points, &cfg, &ClusterConfig::local()).unwrap();
    let t_exact = sw.secs();
    let sw = isospark::util::Stopwatch::start();
    let lm = landmark::run(&ds.points, &cfg, 64, &ClusterConfig::local(), &Backend::Native)
        .unwrap();
    let t_lm = sw.secs();
    assert!(t_lm < t_exact, "landmark {t_lm}s vs exact {t_exact}s");
    let err = isospark::eval::procrustes(&exact.embedding, &lm.embedding);
    assert!(err < 0.05, "landmark vs exact procrustes = {err}");
}

#[test]
fn cli_binary_runs() {
    // Smoke the launcher end-to-end (run + scale-table + info).
    let bin = env!("CARGO_BIN_EXE_isospark");
    let out = std::process::Command::new(bin)
        .args(["run", "--dataset", "swiss", "--n", "128", "--k", "8", "--block", "32"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("procrustes"), "stdout: {stdout}");

    let out = std::process::Command::new(bin)
        .args(["scale-table", "--nodes-list", "2,4"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("Table I"));

    let out = std::process::Command::new(bin).arg("info").output().unwrap();
    assert!(out.status.success());

    let out = std::process::Command::new(bin).arg("nonsense").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn all_pipelines_agree_on_regression_seed() {
    // Seed 23 once exposed a corner-shortcut bug in the swiss-roll
    // geometry (see data::swiss_roll::SPIRAL_A docs). Keep it as a
    // regression: exact Isomap, L-Isomap and the streaming batch must all
    // recover the latents, and landmark == streaming-batch bit-for-bit
    // (same algorithm, two implementations).
    use isospark::coordinator::{landmark, streaming::StreamingModel};
    use isospark::eval::procrustes;
    let ds = swiss_roll::euler_isometric(600, 23);
    let cfg = IsomapConfig { k: 10, d: 2, block: 64, ..Default::default() };
    let truth = ds.ground_truth.as_ref().unwrap();
    let exact = isomap::run(&ds.points, &cfg, &ClusterConfig::local()).unwrap();
    assert!(procrustes(truth, &exact.embedding) < 5e-3);
    let lm =
        landmark::run(&ds.points, &cfg, 100, &ClusterConfig::local(), &Backend::Native).unwrap();
    assert!(procrustes(truth, &lm.embedding) < 5e-3);
    let model =
        StreamingModel::fit(&ds.points, &cfg, 100, &ClusterConfig::local(), &Backend::Native)
            .unwrap();
    assert!(procrustes(truth, &model.batch_embedding) < 5e-3);
    assert!(procrustes(&lm.embedding, &model.batch_embedding) < 1e-10);
}
