//! Parallel-executor determinism: the multi-core stage executor and the
//! zero-copy/copy-on-write shuffle payloads are pure wall-clock
//! optimizations. For any worker-pool size, every pipeline must produce
//! **bit-identical** numerical output and an **identical** lineage/metrics
//! structure (stage count, task count, lineage DAG size) versus
//! `parallelism = 1` sequential execution — across ragged-block and
//! checkpointed APSP configurations.

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::{apsp, centering, dense_from_blocks, isomap, knn};
use isospark::data::swiss_roll;
use isospark::engine::SparkContext;
use isospark::linalg::Matrix;

/// Bit-exact matrix comparison (handles ∞ exactly; NaN never appears).
fn assert_bits_equal(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.nrows(), a.ncols()), (b.nrows(), b.ncols()), "{what}: shape");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i} differs: {x} vs {y}");
    }
}

/// Local-mode cluster with `threads` physical workers. `cores_per_node`
/// is raised to 4 so `default_partitions` yields multiple partitions per
/// stage — otherwise every stage would be a single task and the pool
/// would trivially degenerate to sequential execution. The partition
/// count depends on the *simulated* cores only, so both sides of every
/// comparison see the identical dataflow.
fn cluster(threads: usize) -> ClusterConfig {
    ClusterConfig { parallelism: threads, cores_per_node: 4, ..ClusterConfig::local() }
}

/// Run kNN → APSP → centering and return the densified centered feature
/// matrix plus the engine's structural counters.
fn pipeline_fingerprint(
    n: usize,
    b: usize,
    k: usize,
    checkpoint_every: usize,
    threads: usize,
) -> (Matrix, usize, usize, usize) {
    let ds = swiss_roll::euler_isometric(n, 21);
    let ctx = SparkContext::new(cluster(threads));
    let cfg = IsomapConfig { k, block: b, checkpoint_every, ..Default::default() };
    let be = Backend::Native;
    let kg = knn::build(&ctx, &ds.points, &cfg, &be).unwrap();
    let a = apsp::solve(kg.graph, kg.q, &cfg, &be).unwrap();
    let (centered, _mu) = centering::center(a, n, b, &be).unwrap();
    let dense = dense_from_blocks(&centered, n, b);
    (dense, ctx.total_tasks(), ctx.stage_count(), ctx.lineage_len())
}

#[test]
fn apsp_pipeline_bit_identical_ragged_blocks() {
    // n = 50, b = 16 leaves a ragged last block (q = 4, tail of 2 rows).
    let (seq, seq_tasks, seq_stages, seq_lineage) = pipeline_fingerprint(50, 16, 6, 10, 1);
    let (par, par_tasks, par_stages, par_lineage) = pipeline_fingerprint(50, 16, 6, 10, 4);
    assert_bits_equal(&seq, &par, "centered features (ragged)");
    assert_eq!(seq_tasks, par_tasks, "task count");
    assert_eq!(seq_stages, par_stages, "stage count");
    assert_eq!(seq_lineage, par_lineage, "lineage size");
}

#[test]
fn apsp_pipeline_bit_identical_checkpointed() {
    // Aggressive checkpoint cadence exercises persist + lineage pruning
    // interleaved with the copy-on-write join_update phases.
    let (seq, seq_tasks, seq_stages, seq_lineage) = pipeline_fingerprint(48, 8, 5, 2, 1);
    let (par, par_tasks, par_stages, par_lineage) = pipeline_fingerprint(48, 8, 5, 2, 8);
    assert_bits_equal(&seq, &par, "centered features (checkpointed)");
    assert_eq!(seq_tasks, par_tasks, "task count");
    assert_eq!(seq_stages, par_stages, "stage count");
    assert_eq!(seq_lineage, par_lineage, "lineage size");
}

#[test]
fn full_embedding_bit_identical() {
    // End-to-end Isomap (kNN + APSP + centering + power iteration): the
    // embedding and spectrum must match bit-for-bit across pool sizes.
    let ds = swiss_roll::euler_isometric(96, 31);
    let cfg = IsomapConfig { k: 8, d: 2, block: 32, ..Default::default() };
    let seq = isomap::run(&ds.points, &cfg, &cluster(1)).unwrap();
    let par = isomap::run(&ds.points, &cfg, &cluster(4)).unwrap();
    assert_bits_equal(&seq.embedding, &par.embedding, "embedding");
    assert_eq!(seq.eigen_iterations, par.eigen_iterations);
    for (a, b) in seq.eigenvalues.iter().zip(&par.eigenvalues) {
        assert_eq!(a.to_bits(), b.to_bits(), "eigenvalue differs: {a} vs {b}");
    }
}

#[test]
fn auto_parallelism_matches_sequential() {
    // parallelism = 0 (auto-detect all cores) is the paper_testbed default;
    // it must be just as deterministic.
    let ds = swiss_roll::euler_isometric(64, 5);
    let cfg = IsomapConfig { k: 7, d: 2, block: 16, ..Default::default() };
    let seq = isomap::run(&ds.points, &cfg, &cluster(1)).unwrap();
    let auto = isomap::run(&ds.points, &cfg, &cluster(0)).unwrap();
    assert_bits_equal(&seq.embedding, &auto.embedding, "embedding (auto pool)");
}

#[test]
fn shuffle_accounting_independent_of_pool_size() {
    // Zero-copy payloads must not change the simulated network model:
    // total shuffled bytes are a function of the dataflow alone.
    let bytes = |threads: usize| -> u64 {
        let ds = swiss_roll::euler_isometric(60, 9);
        let mut cl = ClusterConfig::paper_testbed(4);
        cl.parallelism = threads;
        let ctx = SparkContext::new(cl);
        let cfg = IsomapConfig { k: 6, block: 16, ..Default::default() };
        let be = Backend::Native;
        let kg = knn::build(&ctx, &ds.points, &cfg, &be).unwrap();
        let _ = apsp::solve(kg.graph, kg.q, &cfg, &be).unwrap();
        ctx.total_shuffle_bytes()
    };
    assert_eq!(bytes(1), bytes(4));
}
