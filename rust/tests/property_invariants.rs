//! Property-based tests over coordinator invariants (proptest is not
//! available offline; properties are swept with seeded random instances —
//! 20+ cases each, deterministic and reproducible by seed).

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::{apsp, blocks_from_dense, dense_from_blocks, knn, num_blocks};
use isospark::engine::partitioner::UpperTriangularPartitioner;
use isospark::engine::{Partitioner, SparkContext};
use isospark::linalg::Matrix;
use isospark::util::Rng;
use std::sync::Arc;

fn random_points(n: usize, d: usize, rng: &mut Rng) -> Matrix {
    let mut x = Matrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            x[(i, j)] = rng.gaussian();
        }
    }
    x
}

fn random_symmetric_graph(n: usize, rng: &mut Rng) -> Matrix {
    let mut g = Matrix::full(n, n, f64::INFINITY);
    for i in 0..n {
        g[(i, i)] = 0.0;
        let j = (i + 1) % n;
        let w = rng.range(0.05, 2.0);
        g[(i, j)] = w;
        g[(j, i)] = w;
        if rng.f64() < 0.4 {
            let r = rng.below(n);
            if r != i {
                let w = rng.range(0.5, 4.0);
                g[(i, r)] = g[(i, r)].min(w);
                g[(r, i)] = g[(r, i)].min(w);
            }
        }
    }
    g
}

fn engine_apsp(g: &Matrix, b: usize) -> Matrix {
    let n = g.nrows();
    let q = num_blocks(n, b);
    let ctx = SparkContext::new(ClusterConfig::local());
    let part: Arc<dyn Partitioner> = Arc::new(UpperTriangularPartitioner::new(q, q));
    let rdd = ctx.parallelize("g", blocks_from_dense(g, b), part);
    let cfg = IsomapConfig { block: b, ..Default::default() };
    let out = apsp::solve(rdd, q, &cfg, &Backend::Native).unwrap();
    dense_from_blocks(&out, n, b).map(|v| v.sqrt())
}

/// Property: APSP output is a metric — symmetric, zero diagonal, triangle
/// inequality — for arbitrary connected weighted graphs and block sizes.
#[test]
fn apsp_output_is_a_metric() {
    for seed in 0..20 {
        let mut rng = Rng::seed(seed);
        let n = 16 + rng.below(33); // 16..48
        let b = 5 + rng.below(12); // 5..16
        let g = random_symmetric_graph(n, &mut rng);
        let d = engine_apsp(&g, b);
        for i in 0..n {
            assert!(d[(i, i)].abs() < 1e-12, "seed {seed}: nonzero diagonal");
            for j in 0..n {
                assert!((d[(i, j)] - d[(j, i)]).abs() < 1e-9, "seed {seed}: asymmetry");
            }
        }
        // Spot-check the triangle inequality on random triples.
        for _ in 0..200 {
            let (i, j, k) = (rng.below(n), rng.below(n), rng.below(n));
            assert!(
                d[(i, j)] <= d[(i, k)] + d[(k, j)] + 1e-9,
                "seed {seed}: triangle violation"
            );
        }
    }
}

/// Property: APSP never increases any entry (paths only shorten) and is
/// dominated by the input edge weights.
#[test]
fn apsp_dominated_by_input() {
    for seed in 20..35 {
        let mut rng = Rng::seed(seed);
        let n = 20 + rng.below(20);
        let b = 4 + rng.below(10);
        let g = random_symmetric_graph(n, &mut rng);
        let d = engine_apsp(&g, b);
        for i in 0..n {
            for j in 0..n {
                if g[(i, j)].is_finite() {
                    assert!(d[(i, j)] <= g[(i, j)] + 1e-9, "seed {seed}");
                }
            }
        }
    }
}

/// Property: block size never changes the kNN result (routing invariance).
#[test]
fn knn_block_size_invariance() {
    for seed in 0..10 {
        let mut rng = Rng::seed(seed + 100);
        let n = 40 + rng.below(40);
        let x = random_points(n, 1 + rng.below(6), &mut rng);
        let k = 3 + rng.below(5);
        let reference: Vec<Vec<usize>> = {
            let cfg = IsomapConfig { k, block: n, ..Default::default() };
            let ctx = SparkContext::new(ClusterConfig::local());
            let kg = knn::build(&ctx, &x, &cfg, &Backend::Native).unwrap();
            kg.lists.iter().map(|l| l.iter().map(|&(_, j)| j).collect()).collect()
        };
        for b in [7usize, 16, 33] {
            let cfg = IsomapConfig { k, block: b, ..Default::default() };
            let ctx = SparkContext::new(ClusterConfig::local());
            let kg = knn::build(&ctx, &x, &cfg, &Backend::Native).unwrap();
            let got: Vec<Vec<usize>> =
                kg.lists.iter().map(|l| l.iter().map(|&(_, j)| j).collect()).collect();
            assert_eq!(got, reference, "seed {seed} b={b}");
        }
    }
}

/// Property: every kNN list has exactly k entries, sorted ascending, no
/// self-loops, no duplicates.
#[test]
fn knn_list_wellformedness() {
    for seed in 0..15 {
        let mut rng = Rng::seed(seed + 500);
        let n = 30 + rng.below(50);
        let k = 2 + rng.below(8);
        let x = random_points(n, 3, &mut rng);
        let cfg = IsomapConfig { k, block: 9, ..Default::default() };
        let ctx = SparkContext::new(ClusterConfig::local());
        let kg = knn::build(&ctx, &x, &cfg, &Backend::Native).unwrap();
        for (i, list) in kg.lists.iter().enumerate() {
            assert_eq!(list.len(), k, "seed {seed} point {i}");
            let mut seen = std::collections::BTreeSet::new();
            for w in list.windows(2) {
                assert!(w[0].0 <= w[1].0);
            }
            for &(_, j) in list {
                assert_ne!(j, i, "self-loop");
                assert!(seen.insert(j), "duplicate neighbor");
            }
        }
    }
}

/// Property: the kNN graph blocks are consistent with the lists — every
/// finite off-diagonal entry corresponds to an edge from some list, with
/// the matching distance.
#[test]
fn graph_blocks_consistent_with_lists() {
    for seed in 0..10 {
        let mut rng = Rng::seed(seed + 900);
        let n = 30 + rng.below(30);
        let b = 8;
        let x = random_points(n, 3, &mut rng);
        let cfg = IsomapConfig { k: 5, block: b, ..Default::default() };
        let ctx = SparkContext::new(ClusterConfig::local());
        let kg = knn::build(&ctx, &x, &cfg, &Backend::Native).unwrap();
        let dense = dense_from_blocks(&kg.graph, n, b);
        let mut edges = std::collections::BTreeSet::new();
        for (i, list) in kg.lists.iter().enumerate() {
            for &(_, j) in list {
                edges.insert((i.min(j), i.max(j)));
            }
        }
        for i in 0..n {
            for j in (i + 1)..n {
                // Note: ∞ marks no edge; dense_from_blocks mirrors UT.
                if dense[(i, j)].is_finite() && dense[(i, j)] > 0.0 {
                    assert!(edges.contains(&(i, j)), "seed {seed}: stray edge ({i},{j})");
                }
            }
        }
    }
}

/// Property: eigen stage — Q orthonormal and eigenvalues sorted — across
/// random PSD matrices and block sizes.
#[test]
fn eigen_orthonormal_and_sorted() {
    use isospark::coordinator::eigen::simultaneous_power_iteration;
    for seed in 0..12 {
        let mut rng = Rng::seed(seed + 300);
        let n = 24 + rng.below(24);
        let b = 6 + rng.below(10);
        let m0 = random_points(n, n, &mut rng);
        let m = m0.matmul(&m0.transpose()); // PSD
        let ctx = SparkContext::new(ClusterConfig::local());
        let q = num_blocks(n, b);
        let part: Arc<dyn Partitioner> = Arc::new(UpperTriangularPartitioner::new(q, q));
        let rdd = ctx.parallelize("a", blocks_from_dense(&m, b), part);
        let out =
            simultaneous_power_iteration(&rdd, n, b, 2, 1e-8, 200, &Backend::Native).unwrap();
        let qtq = out.q.transpose().matmul(&out.q);
        assert!(qtq.max_abs_diff(&Matrix::eye(2, 2)) < 1e-6, "seed {seed}");
        assert!(out.eigenvalues[0] >= out.eigenvalues[1] - 1e-9, "seed {seed}");
    }
}
