//! Model persistence + serving subsystem, end to end over real sockets:
//!
//! * `save → load → map_points` is bit-identical to the in-memory model;
//! * corrupt / truncated / version-mismatched artifacts fail with context,
//!   never panics;
//! * `POST /v1/embed` over a real loopback TCP connection returns exactly
//!   (bit-for-bit) what in-process `map_points` returns;
//! * `/v1/reload` hot-swaps atomically and a failed reload keeps serving;
//! * concurrent embeds coalesce through the micro-batch queue without
//!   changing a single bit.

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::streaming::StreamingModel;
use isospark::data::swiss_roll;
use isospark::model::FittedModel;
use isospark::serve::{self, client, ServeConfig};
use isospark::util::json::Json;
use std::path::PathBuf;

fn fit_model(n: usize, seed: u64) -> FittedModel {
    let ds = swiss_roll::euler_isometric(n, seed);
    let cfg = IsomapConfig { k: 10, d: 2, block: 64, seed, ..Default::default() };
    let m = (n / 6).max(40);
    StreamingModel::fit(&ds.points, &cfg, m, &ClusterConfig::local(), &Backend::Native)
        .expect("fit")
        .into_model()
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("isospark_serve_it_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn assert_bits_eq(a: &isospark::linalg::Matrix, b: &isospark::linalg::Matrix, what: &str) {
    assert_eq!((a.nrows(), a.ncols()), (b.nrows(), b.ncols()), "{what}: shape");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i}: {x} vs {y}");
    }
}

fn start_default(model: FittedModel, path: Option<PathBuf>) -> serve::ServerHandle {
    serve::start(model, path, None, &ServeConfig { threads: 4, ..Default::default() })
        .expect("start server")
}

#[test]
fn save_load_map_points_bit_identical() {
    let model = fit_model(300, 11);
    let dir = tmp_dir("roundtrip");
    model.save(&dir).unwrap();
    let loaded = FittedModel::load(&dir).unwrap();
    assert_bits_eq(&loaded.batch_embedding, &model.batch_embedding, "batch embedding");
    let fresh = swiss_roll::euler_isometric(64, 99).points;
    let a = model.map_points(&fresh).unwrap();
    let b = loaded.map_points(&fresh).unwrap();
    assert_bits_eq(&a, &b, "map_points after reload");
}

#[test]
fn corrupt_and_truncated_artifacts_fail_with_context() {
    let model = fit_model(260, 3);
    let dir = tmp_dir("corrupt");
    model.save(&dir).unwrap();

    // Bit-flip inside delta.bin (length preserved): checksum must catch it.
    let dpath = dir.join("delta.bin");
    let mut bytes = std::fs::read(&dpath).unwrap();
    bytes[100] ^= 0xff;
    std::fs::write(&dpath, &bytes).unwrap();
    let err = format!("{:#}", FittedModel::load(&dir).unwrap_err());
    assert!(err.contains("delta.bin") && err.contains("checksum"), "{err}");

    // Restore, then truncate batch.bin: the binary reader must refuse.
    model.save(&dir).unwrap();
    let bpath = dir.join("batch.bin");
    let bytes = std::fs::read(&bpath).unwrap();
    std::fs::write(&bpath, &bytes[..bytes.len() / 2]).unwrap();
    let err = format!("{:#}", FittedModel::load(&dir).unwrap_err());
    assert!(err.contains("batch.bin"), "{err}");

    // Manifest/file disagreement: shrink "d" so eigvals no longer match.
    model.save(&dir).unwrap();
    let mpath = dir.join("model.json");
    let text = std::fs::read_to_string(&mpath).unwrap();
    std::fs::write(&mpath, text.replace("\"d\":2", "\"d\":3")).unwrap();
    assert!(FittedModel::load(&dir).is_err());

    // Unsupported format version is named in the error.
    model.save(&dir).unwrap();
    let text = std::fs::read_to_string(&mpath).unwrap();
    std::fs::write(&mpath, text.replace("\"format_version\":1", "\"format_version\":42")).unwrap();
    let err = format!("{:#}", FittedModel::load(&dir).unwrap_err());
    assert!(err.contains("format version 42"), "{err}");
}

#[test]
fn loopback_embed_is_bit_identical_to_in_process() {
    let model = fit_model(280, 7);
    let fresh = swiss_roll::euler_isometric(24, 55).points;
    let expected = model.map_points(&fresh).unwrap();
    let handle = start_default(model, None);
    let addr = handle.addr();

    let served = client::embed(&addr, &fresh).unwrap();
    assert_bits_eq(&served, &expected, "served embedding");

    let (code, health) = client::get_json(&addr, "/healthz").unwrap();
    assert_eq!(code, 200);
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(
        health.get("model").and_then(|m| m.get("n")).and_then(Json::as_usize),
        Some(280)
    );

    let (code, metrics) = client::get_json(&addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    let embeds = metrics
        .get("requests")
        .and_then(|r| r.get("embed"))
        .and_then(Json::as_usize)
        .unwrap();
    assert!(embeds >= 1, "embed count {embeds}");
    assert!(metrics.get("embed_latency_us").is_some());
    // Native backend ⇒ no offload counters, reported as null not omitted.
    assert_eq!(metrics.get("offload"), Some(&Json::Null));

    handle.shutdown();
}

#[test]
fn reload_hot_swaps_and_failed_reload_keeps_serving() {
    let model_a = fit_model(260, 1);
    let model_b = fit_model(260, 2);
    let dir_a = tmp_dir("reload_a");
    let dir_b = tmp_dir("reload_b");
    model_a.save(&dir_a).unwrap();
    model_b.save(&dir_b).unwrap();
    let fresh = swiss_roll::euler_isometric(16, 77).points;
    let expect_a = model_a.map_points(&fresh).unwrap();
    let expect_b = model_b.map_points(&fresh).unwrap();
    // Different seeds ⇒ different landmarks ⇒ genuinely different frames.
    assert!(expect_a.max_abs_diff(&expect_b) > 0.0, "models indistinguishable");

    let handle = start_default(FittedModel::load(&dir_a).unwrap(), Some(dir_a.clone()));
    let addr = handle.addr();
    assert_bits_eq(&client::embed(&addr, &fresh).unwrap(), &expect_a, "before reload");

    let body = Json::obj(vec![("path", Json::str(dir_b.to_str().unwrap()))]);
    let (code, resp) = client::post_json(&addr, "/v1/reload", &body).unwrap();
    assert_eq!(code, 200, "{resp}");
    assert_bits_eq(&client::embed(&addr, &fresh).unwrap(), &expect_b, "after reload");

    // Reload pointing at garbage: 400, current model keeps serving.
    let bad = Json::obj(vec![("path", Json::str("/nonexistent/model/dir"))]);
    let (code, resp) = client::post_json(&addr, "/v1/reload", &bad).unwrap();
    assert_eq!(code, 400, "{resp}");
    assert!(resp.get("error").is_some());
    assert_bits_eq(&client::embed(&addr, &fresh).unwrap(), &expect_b, "after failed reload");

    // Empty body re-reads the last successful path (dir_b).
    let (code, _) = client::post_json(&addr, "/v1/reload", &Json::obj(vec![])).unwrap();
    assert_eq!(code, 200);
    assert_bits_eq(&client::embed(&addr, &fresh).unwrap(), &expect_b, "after re-reload");

    handle.shutdown();
}

#[test]
fn concurrent_embeds_are_coalesced_and_bit_identical() {
    let model = fit_model(300, 5);
    let fresh = swiss_roll::euler_isometric(64, 31).points;
    let expected = model.map_points(&fresh).unwrap();
    let handle = start_default(model, None);
    let addr = handle.addr();

    // 8 clients × 4 rounds × one disjoint 8-row chunk each.
    let chunks = 8usize;
    let rows = fresh.nrows() / chunks;
    std::thread::scope(|scope| {
        for c in 0..chunks {
            let addr = addr.clone();
            let fresh = &fresh;
            let expected = &expected;
            scope.spawn(move || {
                let mut conn = client::Conn::connect(&addr).unwrap();
                let pts = fresh.slice(c * rows, (c + 1) * rows, 0, fresh.ncols());
                let want = expected.slice(c * rows, (c + 1) * rows, 0, expected.ncols());
                for round in 0..4 {
                    let got = client::embed_on(&mut conn, &pts).unwrap();
                    assert_bits_eq(&got, &want, &format!("chunk {c} round {round}"));
                }
            });
        }
    });

    let (_, metrics) = client::get_json(&addr, "/metrics").unwrap();
    let batching = metrics.get("batching").unwrap();
    let points = batching.get("points").and_then(Json::as_usize).unwrap();
    let batches = batching.get("batches").and_then(Json::as_usize).unwrap();
    assert_eq!(points, chunks * 4 * rows, "every served point is accounted");
    assert!(batches >= 1 && batches <= chunks * 4, "batches {batches}");

    handle.shutdown();
}

#[test]
fn malformed_requests_get_http_errors_not_hangs() {
    let model = fit_model(240, 9);
    let handle = start_default(model, None);
    let addr = handle.addr();

    // Raw garbage: 400 and close.
    {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        s.write_all(b"GARBAGE\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
    }
    // Bad JSON body.
    let mut conn = client::Conn::connect(&addr).unwrap();
    let (code, _) = conn.request("POST", "/v1/embed", Some("{not json")).unwrap();
    assert_eq!(code, 400);
    // Wrong dimensionality (model D is 3).
    let (code, body) = conn
        .request("POST", "/v1/embed", Some("{\"points\": [[1.0, 2.0]]}"))
        .unwrap();
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("dimensionality"), "{body}");
    // Empty points.
    let (code, _) = conn.request("POST", "/v1/embed", Some("{\"points\": []}")).unwrap();
    assert_eq!(code, 400);
    // Unknown path / wrong method.
    let (code, _) = conn.request("GET", "/nope", None).unwrap();
    assert_eq!(code, 404);
    let (code, _) = conn.request("POST", "/healthz", None).unwrap();
    assert_eq!(code, 405);
    // The connection survived all of that (keep-alive) and still serves.
    let fresh = swiss_roll::euler_isometric(4, 12).points;
    let got = client::embed_on(&mut conn, &fresh).unwrap();
    assert_eq!(got.nrows(), 4);

    let (_, metrics) = client::get_json(&addr, "/metrics").unwrap();
    let errors = metrics
        .get("requests")
        .and_then(|r| r.get("errors"))
        .and_then(Json::as_usize)
        .unwrap();
    assert!(errors >= 5, "errors {errors}");

    handle.shutdown();
}

#[test]
fn fit_save_serve_roundtrip_matches_cli_flow() {
    // The acceptance-criteria path as a library-level test: fit → save →
    // load in a "fresh process" → serve → query == in-process map_points.
    let model = fit_model(260, 21);
    let dir = tmp_dir("cli_flow");
    model.save(&dir).unwrap();
    let fresh = swiss_roll::euler_isometric(10, 5).points;
    let expected = model.map_points(&fresh).unwrap();
    drop(model); // only the artifact survives

    let served_model = FittedModel::load(&dir).unwrap();
    let handle = start_default(served_model, Some(dir));
    let got = client::embed(&handle.addr(), &fresh).unwrap();
    assert_bits_eq(&got, &expected, "fit→save→serve roundtrip");
    handle.shutdown();
}
