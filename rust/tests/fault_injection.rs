//! Chaos suite: the fault-tolerance contract, end to end.
//!
//! * Any fault rate × any worker count → the final embedding is
//!   **bit-identical** to the fault-free run (injection keys on the global
//!   task index and retries re-run the same pure task, so recovery is
//!   invisible in the output);
//! * exhausting the attempt budget fails the run with the stage name and
//!   attempt count, not a bare panic;
//! * a run restarted on a populated `--checkpoint-dir` restores the APSP
//!   state durably and still reproduces the uninterrupted embedding
//!   bitwise;
//! * corrupt or truncated checkpoints are detected, skipped, and never
//!   poison the result.

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, GeodesicsMode, IsomapConfig, KnnMode};
use isospark::coordinator::isomap;
use isospark::data::swiss_roll;
use isospark::linalg::Matrix;
use std::panic::AssertUnwindSafe;
use std::path::PathBuf;

fn run(x: &Matrix, cfg: &IsomapConfig, cluster: &ClusterConfig) -> isomap::IsomapOutput {
    isomap::run_with(x, cfg, cluster, &Backend::Native).expect("pipeline run")
}

fn chaos_cluster(parallelism: usize, rate: f64, seed: u64) -> ClusterConfig {
    ClusterConfig {
        parallelism,
        fault_rate: rate,
        fault_seed: seed,
        ..ClusterConfig::local()
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("isospark_chaos_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn assert_bits_eq(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.nrows(), a.ncols()), (b.nrows(), b.ncols()), "{what}: shape");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i}: {x} vs {y}");
    }
}

#[test]
fn dense_pipeline_is_bit_identical_under_faults() {
    // The hard contract: for every fault rate and worker count, the
    // embedding matches the fault-free single-worker run bit for bit.
    let ds = swiss_roll::euler_isometric(96, 17);
    let cfg = IsomapConfig { k: 8, d: 2, block: 32, ..Default::default() };
    let clean = run(&ds.points, &cfg, &ClusterConfig::local());
    assert!(
        !clean.metrics_table.contains("resilience"),
        "fault-free run must not grow a resilience block:\n{}",
        clean.metrics_table
    );

    for rate in [0.1, 0.3] {
        for workers in [1usize, 2, 8] {
            let out = run(&ds.points, &cfg, &chaos_cluster(workers, rate, 7));
            assert_bits_eq(
                &out.embedding,
                &clean.embedding,
                &format!("rate={rate} workers={workers}"),
            );
            assert_eq!(out.eigen_iterations, clean.eigen_iterations);
            assert!(
                out.metrics_table.contains("resilience"),
                "rate {rate} must record injections:\n{}",
                out.metrics_table
            );
        }
    }
}

#[test]
fn subquadratic_pipeline_is_bit_identical_under_faults() {
    // Same contract through the other code path: rp-forest candidates +
    // sparse Dijkstra geodesics (stages "knn:rpforest:*", "geo:dijkstra").
    let ds = swiss_roll::euler_isometric(300, 13);
    let cfg = IsomapConfig {
        k: 10,
        d: 2,
        block: 64,
        knn: KnnMode::RpForest,
        geodesics: GeodesicsMode::SparseDijkstra,
        ..Default::default()
    };
    let clean = run(&ds.points, &cfg, &ClusterConfig::local());
    for workers in [1usize, 4] {
        let out = run(&ds.points, &cfg, &chaos_cluster(workers, 0.3, 11));
        assert_bits_eq(&out.embedding, &clean.embedding, &format!("workers={workers}"));
        assert!(out.metrics_table.contains("resilience"), "{}", out.metrics_table);
    }
}

#[test]
fn exhausted_attempts_fail_with_stage_context() {
    // Rate 1.0: every attempt of every task is served an injected failure,
    // so the first faulted stage must exhaust its budget and name itself.
    let ds = swiss_roll::euler_isometric(40, 3);
    let cfg = IsomapConfig { k: 6, d: 2, block: 16, ..Default::default() };
    let cluster = ClusterConfig { fault_max_attempts: 2, ..chaos_cluster(1, 1.0, 5) };
    let result = std::panic::catch_unwind(AssertUnwindSafe(|| run(&ds.points, &cfg, &cluster)));
    let payload = result.expect_err("rate 1.0 must exhaust every retry budget");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("failed after 2 attempts"), "attempt count lost: {msg:?}");
    assert!(msg.contains("injected"), "injected origin lost: {msg:?}");
}

#[test]
fn apsp_durable_checkpoint_restarts_bitwise() {
    let ds = swiss_roll::euler_isometric(120, 29);
    // q = ⌈120/32⌉ = 4 pivots, durable spills after pivots 2 and 4.
    let cfg = IsomapConfig { k: 8, d: 2, block: 32, checkpoint_every: 2, ..Default::default() };
    let dir = tmp_dir("apsp");
    let durable = ClusterConfig {
        checkpoint_dir: Some(dir.to_str().unwrap().to_string()),
        ..ClusterConfig::local()
    };

    let baseline = run(&ds.points, &cfg, &ClusterConfig::local());

    // First run writes the checkpoints; writing must not change anything.
    let first = run(&ds.points, &cfg, &durable);
    assert_bits_eq(&first.embedding, &baseline.embedding, "durable spill run");
    assert!(
        first.metrics_table.contains("checkpoint:durable"),
        "no durable spill recorded:\n{}",
        first.metrics_table
    );
    let job_dir = std::fs::read_dir(&dir)
        .expect("checkpoint root exists")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.file_name().is_some_and(|n| n.to_string_lossy().starts_with("apsp-")))
        .expect("one apsp job directory");
    assert!(job_dir.join("step-2").join("manifest.json").exists());
    assert!(job_dir.join("step-4").join("manifest.json").exists());

    // Second run restores the newest checkpoint instead of recomputing.
    let restored = run(&ds.points, &cfg, &durable);
    assert_bits_eq(&restored.embedding, &baseline.embedding, "restored run");
    assert!(
        restored.metrics_table.contains("checkpoint:restore"),
        "restart did not restore:\n{}",
        restored.metrics_table
    );

    // Corrupt the newest spill: restore must fall back to step 2, replay
    // the remaining pivots, and still land on the identical embedding.
    let block = std::fs::read_dir(job_dir.join("step-4"))
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.file_name().is_some_and(|n| n.to_string_lossy().starts_with("block-")))
        .expect("a block file in step-4");
    let mut bytes = std::fs::read(&block).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    std::fs::write(&block, &bytes).unwrap();
    let after_corrupt = run(&ds.points, &cfg, &durable);
    assert_bits_eq(&after_corrupt.embedding, &baseline.embedding, "corrupt step skipped");
    assert!(after_corrupt.metrics_table.contains("checkpoint:restore"));

    // Ruin every remaining step (manifest gone = killed mid-spill): the
    // run degrades to a full recompute, still bitwise identical.
    for step in ["step-2", "step-4"] {
        let _ = std::fs::remove_file(job_dir.join(step).join("manifest.json"));
    }
    let from_scratch = run(&ds.points, &cfg, &durable);
    assert_bits_eq(&from_scratch.embedding, &baseline.embedding, "all steps unusable");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn faults_and_durable_checkpoints_compose() {
    // Chaos *and* a restart from durable state at once — the combination
    // the whole subsystem exists for — must still be invisible bitwise.
    let ds = swiss_roll::euler_isometric(100, 41);
    let cfg = IsomapConfig { k: 8, d: 2, block: 32, checkpoint_every: 1, ..Default::default() };
    let dir = tmp_dir("compose");
    let baseline = run(&ds.points, &cfg, &ClusterConfig::local());
    let cluster = ClusterConfig {
        checkpoint_dir: Some(dir.to_str().unwrap().to_string()),
        ..chaos_cluster(4, 0.25, 19)
    };
    let chaotic = run(&ds.points, &cfg, &cluster);
    assert_bits_eq(&chaotic.embedding, &baseline.embedding, "chaos + spill");
    let restarted = run(&ds.points, &cfg, &cluster);
    assert_bits_eq(&restarted.embedding, &baseline.embedding, "chaos + restore");
    assert!(restarted.metrics_table.contains("checkpoint:restore"));
    let _ = std::fs::remove_dir_all(&dir);
}
