//! Property tests for the register-blocked kernel suite: every tiled
//! kernel is checked against a naive reference on tile-boundary shapes —
//! size 1, tile−1, tile, tile+1, multi-tile ragged — plus ∞-dense and
//! `BIG`-valued inputs. The min-plus comparisons assert *bit* equality
//! (min is associative/commutative and each `+` is a single correctly-
//! rounded op, so tiling must not move a single ulp); the Gram/gemm
//! comparisons are tolerance-based against mathematically different
//! formulations, plus exact decomposition-invariance checks for the
//! properties the coordinator relies on.

use isospark::kernels::kselect::{cols_topk, row_topk};
use isospark::kernels::tiling::{J_TILE, MR, NR};
use isospark::kernels::{matvec, minplus, sqdist, BIG};
use isospark::linalg::Matrix;
use isospark::util::Rng;

/// Shapes straddling a tile boundary: 1, tile−1, tile, tile+1, and a
/// multi-tile ragged size.
fn boundary_sizes(tile: usize) -> [usize; 5] {
    [1, tile - 1, tile, tile + 1, 2 * tile + 3]
}

fn random_weights(m: usize, n: usize, inf_density: f64, seed: u64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut a = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            a[(i, j)] =
                if rng.f64() < inf_density { f64::INFINITY } else { rng.range(0.0, 10.0) };
        }
    }
    a
}

fn random_points(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut x = Matrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            x[(i, j)] = rng.gaussian();
        }
    }
    x
}

fn naive_minplus(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.nrows(), b.ncols());
    for i in 0..a.nrows() {
        for j in 0..b.ncols() {
            let mut best = f64::INFINITY;
            for k in 0..a.ncols() {
                best = best.min(a[(i, k)] + b[(k, j)]);
            }
            c[(i, j)] = best;
        }
    }
    c
}

fn naive_dist(xi: &Matrix, xj: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(xi.nrows(), xj.nrows());
    for i in 0..xi.nrows() {
        for j in 0..xj.nrows() {
            let d: f64 =
                xi.row(i).iter().zip(xj.row(j)).map(|(a, b)| (a - b) * (a - b)).sum();
            out[(i, j)] = d.sqrt();
        }
    }
    out
}

fn assert_bits(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.nrows(), a.ncols()), (b.nrows(), b.ncols()), "{what}: shape");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i}: {x} vs {y}");
    }
}

#[test]
fn minplus_bit_equals_naive_on_boundary_shapes() {
    let mut seed = 0;
    for m in boundary_sizes(J_TILE) {
        for n in boundary_sizes(J_TILE) {
            for kk in [1usize, 5, J_TILE + 1] {
                seed += 1;
                let a = random_weights(m, kk, 0.2, seed);
                let b = random_weights(kk, n, 0.2, seed + 1000);
                let got = minplus::minplus(&a, &b);
                let want = naive_minplus(&a, &b);
                assert_bits(&got, &want, &format!("minplus m={m} k={kk} n={n}"));
            }
        }
    }
}

#[test]
fn minplus_fused_update_bit_equals_naive() {
    // Nontrivial dst: the fused min with the existing value must survive
    // tiling bit-for-bit, including ragged widths.
    for n in boundary_sizes(J_TILE) {
        let a = random_weights(9, 7, 0.3, n as u64);
        let b = random_weights(7, n, 0.3, n as u64 + 50);
        let mut dst = random_weights(9, n, 0.3, n as u64 + 99);
        let mut want = dst.clone();
        let prod = naive_minplus(&a, &b);
        for (w, &p) in want.as_mut_slice().iter_mut().zip(prod.as_slice()) {
            *w = w.min(p);
        }
        minplus::minplus_into(&a, &b, &mut dst);
        assert_bits(&dst, &want, &format!("minplus_into n={n}"));
    }
}

#[test]
fn minplus_inf_dense_inputs() {
    // Fully-∞ and mostly-∞ operands: the finite-skip fast path must agree
    // with the naive kernel and never produce NaN.
    for density in [1.0, 0.95] {
        let a = random_weights(J_TILE + 1, J_TILE, density, 7);
        let b = random_weights(J_TILE, 2 * J_TILE + 3, density, 8);
        let got = minplus::minplus(&a, &b);
        assert!(got.as_slice().iter().all(|v| !v.is_nan()), "density={density}");
        assert_bits(&got, &naive_minplus(&a, &b), &format!("∞-dense {density}"));
    }
}

#[test]
fn minplus_big_sentinel_values() {
    // BIG (the AOT no-edge sentinel) is finite, so it takes the normal
    // path: BIG + BIG must not overflow to ∞ surprises in the tiled path.
    let mut a = Matrix::full(J_TILE + 2, J_TILE + 2, BIG);
    a[(0, 1)] = 1.5;
    let got = minplus::minplus(&a, &a);
    assert_bits(&got, &naive_minplus(&a, &a), "BIG-dense");
    assert!(got.as_slice().iter().all(|v| v.is_finite()));
}

#[test]
fn inplace_pivots_bit_equal_cloned_form_on_boundary_shapes() {
    for b in [1usize, J_TILE - 1, J_TILE, J_TILE + 1] {
        for n in [1usize, J_TILE - 1, J_TILE + 1, 2 * J_TILE + 3] {
            let d = random_weights(b, b, 0.2, (b * n) as u64);
            let a0 = random_weights(b, n, 0.2, (b * n) as u64 + 31);
            // Left: A ← A ⊕ (D ⊗ A₀).
            let mut left = a0.clone();
            minplus::minplus_left_inplace(&d, &mut left);
            let mut want = a0.clone();
            minplus::minplus_into(&d, &a0, &mut want);
            assert_bits(&left, &want, &format!("left b={b} n={n}"));
            // Right: A ← A ⊕ (A₀ ⊗ D), transposed extents.
            let a0t = random_weights(n, b, 0.2, (b * n) as u64 + 67);
            let mut right = a0t.clone();
            minplus::minplus_right_inplace(&d, &mut right);
            let mut want = a0t.clone();
            minplus::minplus_into(&a0t, &d, &mut want);
            assert_bits(&right, &want, &format!("right b={b} n={n}"));
        }
    }
}

#[test]
fn dist_matches_naive_on_boundary_shapes() {
    let mut seed = 500;
    for bi in boundary_sizes(MR) {
        for bj in boundary_sizes(NR) {
            for d in [1usize, NR - 1, NR, NR + 1] {
                seed += 1;
                let xi = random_points(bi, d, seed);
                let xj = random_points(bj, d, seed + 1000);
                let got = sqdist::dist_block(&xi, &xj);
                let want = naive_dist(&xi, &xj);
                assert!(
                    got.max_abs_diff(&want) < 1e-9,
                    "dist bi={bi} bj={bj} d={d}: {}",
                    got.max_abs_diff(&want)
                );
            }
        }
    }
}

#[test]
fn dist_is_decomposition_invariant() {
    // The engine computes pair distances from *block* slices while the
    // dense references use the whole matrix; the kernel must give
    // bit-identical values for a pair regardless of which block its rows
    // sit in (each dot is one k-ascending chain per pair).
    let x = random_points(3 * MR + 1, 9, 77);
    let n = x.nrows();
    let full = sqdist::dist_block(&x, &x);
    let split = MR + 1; // ragged split
    let (top, bot) = (x.slice(0, split, 0, 9), x.slice(split, n, 0, 9));
    let cross = sqdist::dist_block(&top, &bot);
    for i in 0..split {
        for j in split..n {
            assert_eq!(
                cross[(i, j - split)].to_bits(),
                full[(i, j)].to_bits(),
                "pair ({i},{j})"
            );
        }
    }
}

#[test]
fn dist_sym_upper_mirror_properties() {
    for n in [1usize, MR, NR + 1, 2 * NR + 3, 21] {
        let x = random_points(n, 5, n as u64 + 300);
        let sym = sqdist::dist_block_sym(&x);
        let full = sqdist::dist_block(&x, &x);
        for i in 0..n {
            assert_eq!(sym[(i, i)], 0.0, "n={n} diag {i}");
            for j in 0..n {
                assert_eq!(sym[(i, j)].to_bits(), sym[(j, i)].to_bits(), "n={n} sym ({i},{j})");
                if i != j {
                    assert_eq!(
                        sym[(i, j)].to_bits(),
                        full[(i, j)].to_bits(),
                        "n={n} vs general ({i},{j})"
                    );
                }
            }
        }
    }
}

#[test]
fn dist_far_from_origin_stays_nonnegative() {
    // Catastrophic cancellation in ‖x‖²+‖y‖²−2·x·y on clustered
    // far-from-origin points must be clamped, not NaN/negative.
    let mut rng = Rng::seed(9);
    let mut x = Matrix::full(NR + 3, 4, 1e8);
    for v in x.as_mut_slice() {
        *v += rng.f64() * 1e-4;
    }
    for m in [sqdist::dist_block(&x, &x), sqdist::dist_block_sym(&x)] {
        assert!(m.as_slice().iter().all(|&v| v >= 0.0 && v.is_finite()));
    }
}

#[test]
fn gemm_matches_matmul_on_boundary_shapes() {
    for d in [1usize, 4, 5, J_TILE - 1, J_TILE, J_TILE + 1, 2 * J_TILE + 3] {
        for bj in [1usize, MR, MR + 1] {
            let a = random_points(7, bj, (d * 10 + bj) as u64);
            let q = random_points(bj, d, (d * 10 + bj) as u64 + 5);
            let mut out = random_points(7, d, (d * 10 + bj) as u64 + 9);
            let mut want = out.clone();
            matvec::gemm_acc(&a, &q, &mut out);
            let prod = a.matmul(&q);
            for (w, &p) in want.as_mut_slice().iter_mut().zip(prod.as_slice()) {
                *w += p;
            }
            assert!(out.max_abs_diff(&want) < 1e-10, "gemm d={d} bj={bj}");

            let qt = random_points(7, d, (d * 10 + bj) as u64 + 13);
            let mut out_t = random_points(bj, d, (d * 10 + bj) as u64 + 17);
            let mut want_t = out_t.clone();
            matvec::gemm_t_acc(&a, &qt, &mut out_t);
            let prod_t = a.transpose().matmul(&qt);
            for (w, &p) in want_t.as_mut_slice().iter_mut().zip(prod_t.as_slice()) {
                *w += p;
            }
            assert!(out_t.max_abs_diff(&want_t) < 1e-10, "gemm_t d={d} bj={bj}");
        }
    }
}

#[test]
fn cols_topk_bit_equals_scalar_gather() {
    let mut rng = Rng::seed(42);
    for (r, c) in [(1usize, 1usize), (MR, NR), (31, 33), (33, 31), (70, 40)] {
        let mut m = Matrix::zeros(r, c);
        for i in 0..r {
            for j in 0..c {
                // Duplicated values exercise tie-breaking by index.
                m[(i, j)] = (rng.f64() * 8.0).floor();
            }
        }
        for k in [1usize, 3, r + 2] {
            let got = cols_topk(&m, k, 5);
            assert_eq!(got.len(), c);
            for (j, list) in got.iter().enumerate() {
                let col: Vec<f64> = (0..r).map(|i| m[(i, j)]).collect();
                assert_eq!(list, &row_topk(&col, k, 5, None), "r={r} c={c} k={k} col {j}");
            }
        }
    }
}
