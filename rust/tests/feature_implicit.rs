//! The implicit feature-matrix path (`--feature implicit`), end to end:
//! procrustes agreement and *bit*-identity with the materialized
//! sparse-dijkstra run on the same graph, invariance under worker count
//! and fault injection, the measured peak-resident-bytes separation that
//! is the whole point of the refactor, and the config guard rails.

use isospark::config::{ClusterConfig, FeatureMode, GeodesicsMode, IsomapConfig, KnnMode};
use isospark::coordinator::isomap::{self, IsomapOutput};
use isospark::data::swiss_roll;
use isospark::eval::procrustes;
use isospark::linalg::Matrix;

fn sparse_cfg(k: usize, block: usize, feature: FeatureMode) -> IsomapConfig {
    IsomapConfig {
        k,
        d: 2,
        block,
        feature,
        geodesics: GeodesicsMode::SparseDijkstra,
        ..Default::default()
    }
}

fn run(n: usize, cfg: &IsomapConfig, cluster: &ClusterConfig) -> IsomapOutput {
    let ds = swiss_roll::euler_isometric(n, 13);
    isomap::run(&ds.points, cfg, cluster).unwrap()
}

fn embedding_bits(e: &Matrix) -> Vec<u64> {
    e.as_slice().iter().map(|v| v.to_bits()).collect()
}

#[test]
fn implicit_matches_materialized_procrustes() {
    // The satellite acceptance bound: < 1e-8 between the two feature
    // paths on the paper's swiss-roll setup.
    let cfg_m = sparse_cfg(10, 128, FeatureMode::Materialized);
    let cfg_i = sparse_cfg(10, 128, FeatureMode::Implicit);
    let mat = run(600, &cfg_m, &ClusterConfig::local());
    let imp = run(600, &cfg_i, &ClusterConfig::local());
    assert_eq!(imp.feature, FeatureMode::Implicit);
    assert_eq!(mat.feature, FeatureMode::Materialized);
    let err = procrustes(&mat.embedding, &imp.embedding);
    assert!(err < 1e-8, "implicit vs materialized procrustes = {err}");
}

#[test]
fn implicit_embedding_is_bit_identical_to_materialized() {
    // Stronger than procrustes: the panel source replays the blocked
    // computation exactly (same Dijkstra rows, same squared slices, same
    // per-key accumulation order), so on the same graph the embeddings
    // agree to the last bit. Ragged tail on purpose: 180 = 2·64 + 52.
    let mat = run(180, &sparse_cfg(10, 64, FeatureMode::Materialized), &ClusterConfig::local());
    let imp = run(180, &sparse_cfg(10, 64, FeatureMode::Implicit), &ClusterConfig::local());
    assert_eq!(mat.eigen_iterations, imp.eigen_iterations);
    assert_eq!(
        embedding_bits(&mat.embedding),
        embedding_bits(&imp.embedding),
        "implicit embedding must be bit-identical to materialized"
    );
    for (a, b) in mat.eigenvalues.iter().zip(&imp.eigenvalues) {
        assert_eq!(a.to_bits(), b.to_bits(), "eigenvalues must be bit-identical");
    }
    // One panel sweep for the means plus one per power iteration.
    let q = 3;
    assert_eq!(imp.panel_recomputes, q * (1 + imp.eigen_iterations));
    assert_eq!(imp.panel_spill_reads, 0);
}

#[test]
fn implicit_is_bit_identical_across_worker_counts() {
    let base = {
        let cluster = ClusterConfig { parallelism: 1, ..ClusterConfig::local() };
        run(300, &sparse_cfg(10, 64, FeatureMode::Implicit), &cluster)
    };
    for workers in [2, 8] {
        let cluster =
            ClusterConfig { parallelism: workers, cores_per_node: 4, ..ClusterConfig::local() };
        let out = run(300, &sparse_cfg(10, 64, FeatureMode::Implicit), &cluster);
        assert_eq!(
            embedding_bits(&base.embedding),
            embedding_bits(&out.embedding),
            "workers = {workers}"
        );
    }
}

#[test]
fn implicit_is_bit_identical_under_fault_injection() {
    let clean = run(200, &sparse_cfg(10, 64, FeatureMode::Implicit), &ClusterConfig::local());
    assert!(!clean.metrics_table.contains("resilience"), "{}", clean.metrics_table);
    let faulty_cluster = ClusterConfig {
        parallelism: 4,
        cores_per_node: 4,
        fault_rate: 0.3,
        fault_seed: 9,
        ..ClusterConfig::local()
    };
    let faulty = run(200, &sparse_cfg(10, 64, FeatureMode::Implicit), &faulty_cluster);
    assert_eq!(
        embedding_bits(&clean.embedding),
        embedding_bits(&faulty.embedding),
        "fault injection must not change the embedding"
    );
    // At 30% the panel stages really saw failures.
    assert!(faulty.metrics_table.contains("resilience"), "{}", faulty.metrics_table);
}

#[test]
fn implicit_peak_memory_is_strictly_below_materialized() {
    // The acceptance measurement at n = 2048, b = 256. rp-forest for BOTH
    // runs: the exact kNN front end persists O(n²) distance blocks, which
    // would dominate both peaks and mask the feature-matrix difference.
    // Materialized must peak at O(n²) (the resident feature blocks);
    // implicit at O(n·k + b·n) (CSR graph + one live panel). A handful of
    // iterations is plenty — the peak is set by residency, not iterations.
    let cfg = |feature| IsomapConfig {
        max_iter: 5,
        tol: 1e-30,
        knn: KnnMode::RpForest,
        ..sparse_cfg(10, 256, feature)
    };
    let mat = run(2048, &cfg(FeatureMode::Materialized), &ClusterConfig::local());
    let imp = run(2048, &cfg(FeatureMode::Implicit), &ClusterConfig::local());
    assert!(imp.peak_resident_bytes > 0, "implicit peak must be measured");
    assert!(
        imp.peak_resident_bytes < mat.peak_resident_bytes,
        "implicit peak {} must be strictly below materialized peak {}",
        imp.peak_resident_bytes,
        mat.peak_resident_bytes
    );
    // And the asymptotics are visibly different, not marginal: the n×n
    // feature matrix alone is 32 MiB; CSR + one 256×2048 panel is ~4.5 MiB.
    assert!(
        2 * imp.peak_resident_bytes < mat.peak_resident_bytes,
        "implicit {} vs materialized {}",
        imp.peak_resident_bytes,
        mat.peak_resident_bytes
    );
    assert!(mat.metrics_table.contains("peak resident"), "{}", mat.metrics_table);
}

#[test]
fn implicit_requires_sparse_geodesics() {
    let cfg = IsomapConfig {
        feature: FeatureMode::Implicit,
        geodesics: GeodesicsMode::DenseFw,
        ..Default::default()
    };
    let ds = swiss_roll::euler_isometric(100, 13);
    let err = isomap::run(&ds.points, &cfg, &ClusterConfig::local()).unwrap_err();
    assert!(err.to_string().contains("sparse-dijkstra"), "{err}");
}

#[test]
fn implicit_spill_rereads_panels_and_stays_bit_identical() {
    // With --checkpoint-dir, the build sweep spills each squared panel
    // once; every matvec sweep then re-reads instead of recomputing, and
    // the embedding must not move by a bit.
    let dir = std::env::temp_dir().join(format!("isospark-feat-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let plain = run(180, &sparse_cfg(10, 64, FeatureMode::Implicit), &ClusterConfig::local());
    let spill_cluster = ClusterConfig {
        checkpoint_dir: Some(dir.to_string_lossy().into_owned()),
        ..ClusterConfig::local()
    };
    let spilled = run(180, &sparse_cfg(10, 64, FeatureMode::Implicit), &spill_cluster);
    assert_eq!(
        embedding_bits(&plain.embedding),
        embedding_bits(&spilled.embedding),
        "spill variant must be bit-identical"
    );
    let q = 3;
    assert_eq!(spilled.panel_recomputes, q, "spill run recomputes only the build sweep");
    assert_eq!(spilled.panel_spill_reads, q * spilled.eigen_iterations);
    assert!(spilled.metrics_table.contains("resilience"), "{}", spilled.metrics_table);
    let _ = std::fs::remove_dir_all(&dir);
}
