//! Engine semantics: the Spark-substitute's transformations, partitioners,
//! virtual clock, network model, lineage and memory accounting — exercised
//! through the public API across multi-node simulated clusters.

use isospark::config::ClusterConfig;
use isospark::engine::partitioner::{ut_count, UpperTriangularPartitioner};
use isospark::engine::{BlockId, HashPartitioner, Partitioner, SparkContext};
use isospark::linalg::Matrix;
use std::sync::Arc;

fn ctx(nodes: usize) -> SparkContext {
    SparkContext::new(ClusterConfig { nodes, ..ClusterConfig::local() })
}

#[test]
fn wordcount_style_pipeline() {
    // flat_map -> reduce_by_key over multiple nodes gives exact results.
    let c = ctx(4);
    let items: Vec<(BlockId, Matrix)> =
        (0..8).map(|i| (BlockId::new(i, i), Matrix::full(2, 2, i as f64))).collect();
    let part: Arc<dyn Partitioner> = Arc::new(HashPartitioner::new(8));
    let rdd = c.parallelize("data", items, part.clone());
    let keyed = rdd.flat_map("emit", |_, m| {
        vec![(BlockId::new(0, 0), m.grand_mean()), (BlockId::new(1, 0), 1.0f64)]
    });
    let reduced = keyed.reduce_by_key("sum", part, |a, b| a + b);
    assert_eq!(*reduced.get(BlockId::new(0, 0)).unwrap(), (0..8).sum::<usize>() as f64);
    assert_eq!(*reduced.get(BlockId::new(1, 0)).unwrap(), 8.0);
}

#[test]
fn results_identical_across_cluster_sizes() {
    // The virtual cluster affects *time*, never *values*.
    let run = |nodes: usize| -> Vec<f64> {
        let c = ctx(nodes);
        let items: Vec<(BlockId, Matrix)> =
            (0..6).map(|i| (BlockId::new(i, i), Matrix::full(3, 3, i as f64 + 1.0))).collect();
        let part: Arc<dyn Partitioner> = Arc::new(HashPartitioner::new(6));
        let rdd = c.parallelize("x", items, part.clone());
        let mapped = rdd.map_values("scale", |_, m| {
            let mut m = m.clone();
            m.scale(2.0);
            m
        });
        let keyed =
            mapped.flat_map("fold", |id, m| vec![(BlockId::new(id.i % 2, 0), m.fro_norm())]);
        let red = keyed.reduce_by_key("sum", part, |a, b| a + b);
        red.collect().values().cloned().collect()
    };
    assert_eq!(run(1), run(7));
}

#[test]
fn shuffle_free_on_single_node() {
    let c = ctx(1);
    let items: Vec<(BlockId, f64)> = (0..10).map(|i| (BlockId::new(i, 0), i as f64)).collect();
    let part: Arc<dyn Partitioner> = Arc::new(HashPartitioner::new(4));
    let rdd = c.parallelize("x", items, part.clone());
    // (parallelize itself charges the driver->executor distribution.)
    let after_load = c.total_shuffle_bytes();
    let red = rdd
        .flat_map("emit", |_, v| vec![(BlockId::new(0, 0), *v)])
        .reduce_by_key("sum", part, |a, b| a + b);
    assert_eq!(*red.get(BlockId::new(0, 0)).unwrap(), 45.0);
    // One node: every shuffle record is co-located; no executor-to-executor
    // network traffic possible.
    assert_eq!(c.total_shuffle_bytes(), after_load);
}

#[test]
fn more_nodes_less_virtual_time_for_parallel_work() {
    let run = |nodes: usize| -> f64 {
        let mut cfg = ClusterConfig::paper_testbed(nodes);
        cfg.cores_per_node = 1;
        let c = SparkContext::new(cfg);
        let items: Vec<(BlockId, Matrix)> =
            (0..32).map(|i| (BlockId::new(i, i), Matrix::full(40, 40, 1.0))).collect();
        let part: Arc<dyn Partitioner> = Arc::new(HashPartitioner::new(32));
        let rdd = c.parallelize("x", items, part);
        let _ = rdd.map_values("work", |_, m| m.matmul(m));
        c.virtual_now()
    };
    let t1 = run(1);
    let t8 = run(8);
    assert!(t8 < t1, "t1={t1} t8={t8}");
}

#[test]
fn ut_partitioner_beats_hash_on_row_access_shuffle() {
    // The paper's locality claim, reduced to its essence: broadcast a
    // diagonal block to its whole block row; the UT packing keeps most of
    // the row co-resident, the hash partitioner scatters it.
    let q = 16;
    let parts = ut_count(q) / 4;
    let volume = |part: Arc<dyn Partitioner>| -> u64 {
        let c = ctx(4);
        let items: Vec<(BlockId, Matrix)> = (0..q)
            .flat_map(|i| (i..q).map(move |j| (BlockId::new(i, j), Matrix::full(8, 8, 1.0))))
            .collect();
        let mut rdd = c.parallelize("g", items, part);
        for piv in 0..q {
            let diag = rdd.filter_blocks("diag", |id| id.i == piv && id.j == piv);
            let msgs = diag.flat_map("bcast_row", |_, m| {
                (piv..q).map(|j| (BlockId::new(piv, j), m.clone())).collect()
            });
            rdd = rdd.join_update("recv", msgs, |_, _, _| {});
        }
        c.total_shuffle_bytes()
    };
    let ut = volume(Arc::new(UpperTriangularPartitioner::new(q, parts)));
    let hash = volume(Arc::new(HashPartitioner::new(parts)));
    assert!(ut < hash, "ut={ut} hash={hash}");
}

#[test]
fn memory_exhaustion_surfaces_as_error() {
    let mut cfg = ClusterConfig::paper_testbed(2);
    cfg.mem_per_node = 10_000; // 10 kB executors
    let c = SparkContext::new(cfg);
    let items: Vec<(BlockId, Matrix)> =
        (0..4).map(|i| (BlockId::new(i, i), Matrix::zeros(64, 64))).collect();
    let part: Arc<dyn Partitioner> = Arc::new(HashPartitioner::new(4));
    let rdd = c.parallelize("big", items, part);
    let err = rdd.persist("big").unwrap_err();
    assert!(format!("{err:#}").contains("impossible"));
}

#[test]
fn lineage_depth_drives_driver_cost() {
    let mut cfg = ClusterConfig::local();
    cfg.sched_overhead = 1e-3;
    let run = |checkpoint: bool| -> f64 {
        let c = SparkContext::new(cfg.clone());
        let items: Vec<(BlockId, f64)> = (0..4).map(|i| (BlockId::new(i, 0), 0.0)).collect();
        let part: Arc<dyn Partitioner> = Arc::new(HashPartitioner::new(4));
        let mut rdd = c.parallelize("x", items, part);
        for i in 0..50 {
            rdd = rdd.map_values("step", |_, v| v + 1.0);
            if checkpoint && i % 10 == 9 {
                rdd.checkpoint();
            }
        }
        c.virtual_now()
    };
    let with = run(true);
    let without = run(false);
    assert!(
        with < without,
        "checkpointing must bound driver overhead: with={with} without={without}"
    );
}

#[test]
fn broadcast_cost_scales_with_cluster() {
    let small = {
        let c = SparkContext::new(ClusterConfig::paper_testbed(2));
        c.broadcast("q", 1 << 24);
        c.virtual_now()
    };
    let large = {
        let c = SparkContext::new(ClusterConfig::paper_testbed(16));
        c.broadcast("q", 1 << 24);
        c.virtual_now()
    };
    assert!(large > small);
}
