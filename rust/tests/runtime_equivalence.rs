//! PJRT backend ↔ native backend equivalence.
//!
//! Loads the AOT artifacts produced by `make artifacts` and asserts that
//! every Pallas-kernel-backed executable agrees with the native Rust
//! kernels to f64 precision, then runs the full pipeline on both backends
//! and compares embeddings. Skips (with a loud message) when artifacts are
//! missing so `cargo test` stays runnable before `make artifacts`.

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::isomap;
use isospark::data::swiss_roll;
use isospark::kernels;
use isospark::linalg::Matrix;
use isospark::runtime::PjrtEngine;
use isospark::util::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine() -> Option<PjrtEngine> {
    match PjrtEngine::load(&artifacts_dir()) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP runtime_equivalence: {err:#} (run `make artifacts`)");
            None
        }
    }
}

fn random(r: usize, c: usize, seed: u64, lo: f64, hi: f64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut m = Matrix::zeros(r, c);
    for i in 0..r {
        for j in 0..c {
            m[(i, j)] = rng.range(lo, hi);
        }
    }
    m
}

/// Random graph block with infinities (the APSP no-edge marker).
fn random_graph(b: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut m = Matrix::full(b, b, f64::INFINITY);
    for i in 0..b {
        m[(i, i)] = 0.0;
        for j in 0..b {
            if i != j && rng.f64() < 0.4 {
                m[(i, j)] = rng.range(0.1, 5.0);
            }
        }
    }
    m
}

#[test]
fn minplus_matches_native() {
    let Some(rt) = engine() else { return };
    for b in [32usize, 64, 128] {
        let a = random_graph(b, 1);
        let c = random_graph(b, 2);
        let got = rt.minplus(&a, &c).expect("minplus artifact");
        let want = kernels::minplus::minplus(&a, &c);
        for (x, y) in got.as_slice().iter().zip(want.as_slice()) {
            if x.is_infinite() || y.is_infinite() {
                assert!(x.is_infinite() && y.is_infinite());
            } else {
                assert!((x - y).abs() < 1e-12, "b={b}: {x} vs {y}");
            }
        }
    }
}

#[test]
fn fw_matches_native() {
    let Some(rt) = engine() else { return };
    for b in [32usize, 64] {
        let g = random_graph(b, 3);
        let got = rt.floyd_warshall(&g).expect("fw artifact");
        let want = kernels::floyd_warshall::floyd_warshall(&g);
        for (x, y) in got.as_slice().iter().zip(want.as_slice()) {
            if x.is_infinite() || y.is_infinite() {
                assert!(x.is_infinite() && y.is_infinite());
            } else {
                assert!((x - y).abs() < 1e-10, "b={b}: {x} vs {y}");
            }
        }
    }
}

#[test]
fn dist_matches_native() {
    let Some(rt) = engine() else { return };
    for (b, dim) in [(32usize, 3usize), (64, 784), (128, 16)] {
        let xi = random(b, dim, 5, -3.0, 3.0);
        let xj = random(b, dim, 6, -3.0, 3.0);
        let got = rt.dist_block(&xi, &xj).expect("dist artifact");
        let want = kernels::sqdist::dist_block(&xi, &xj);
        assert!(got.max_abs_diff(&want) < 1e-9, "b={b} dim={dim}");
    }
}

#[test]
fn center_matches_native() {
    let Some(rt) = engine() else { return };
    let b = 64;
    let blk = random(b, b, 7, 0.0, 50.0);
    let mu_r: Vec<f64> = (0..b).map(|i| i as f64 * 0.1).collect();
    let mu_c: Vec<f64> = (0..b).map(|i| 3.0 - i as f64 * 0.05).collect();
    let got = rt.center_block(&blk, &mu_r, &mu_c, 1.75).expect("center artifact");
    let mut want = blk.clone();
    kernels::centering::center_block(&mut want, &mu_r, &mu_c, 1.75);
    assert!(got.max_abs_diff(&want) < 1e-12);
}

#[test]
fn gemm_matches_native_with_padding() {
    let Some(rt) = engine() else { return };
    let b = 64;
    let a = random(b, b, 8, -2.0, 2.0);
    for d in [2usize, 3, 8] {
        let q = random(b, d, 9, -1.0, 1.0);
        let got = rt.gemm(&a, &q).expect("gemm artifact");
        let mut want = Matrix::zeros(b, d);
        kernels::matvec::gemm_acc(&a, &q, &mut want);
        assert!(got.max_abs_diff(&want) < 1e-11, "d={d}");

        let got_t = rt.gemm_t(&a, &q).expect("gemmt artifact");
        let mut want_t = Matrix::zeros(b, d);
        kernels::matvec::gemm_t_acc(&a, &q, &mut want_t);
        assert!(got_t.max_abs_diff(&want_t) < 1e-11, "t d={d}");
    }
}

#[test]
fn unsupported_shapes_error_cleanly() {
    let Some(rt) = engine() else { return };
    // Ragged block: no artifact — must Err (backend falls back to native).
    assert!(rt.minplus(&Matrix::zeros(33, 33), &Matrix::zeros(33, 33)).is_err());
    assert!(rt.dist_block(&Matrix::zeros(32, 5), &Matrix::zeros(32, 5)).is_err());
}

#[test]
fn full_pipeline_pjrt_equals_native() {
    if engine().is_none() {
        return;
    }
    let backend = Backend::pjrt_from_dir(&artifacts_dir()).expect("pjrt backend");
    // n divisible by b so the hot path stays on PJRT end-to-end.
    let ds = swiss_roll::euler_isometric(256, 41);
    let cfg = IsomapConfig { k: 10, d: 2, block: 64, ..Default::default() };
    let cl = ClusterConfig::local();
    let native = isomap::run_with(&ds.points, &cfg, &cl, &Backend::Native).unwrap();
    let pjrt = isomap::run_with(&ds.points, &cfg, &cl, &backend).unwrap();
    assert_eq!(native.embedding.nrows(), pjrt.embedding.nrows());
    let diff = native.embedding.max_abs_diff(&pjrt.embedding);
    assert!(diff < 1e-6, "pjrt vs native embedding max diff = {diff}");
    for (a, b) in native.eigenvalues.iter().zip(&pjrt.eigenvalues) {
        assert!((a - b).abs() / a.abs().max(1.0) < 1e-9);
    }
}
