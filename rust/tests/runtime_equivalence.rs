//! PJRT backend ↔ native backend equivalence.
//!
//! Loads the AOT artifacts produced by `make artifacts` and asserts that
//! every Pallas-kernel-backed executable agrees with the native Rust
//! kernels to f64 precision — on exact artifact shapes *and* on ragged
//! (`b ∤ n`) shapes served through the shape-polymorphic padded path —
//! then runs the full pipeline on both backends and compares embeddings,
//! checking that offload coverage stays at 100% (zero counted fallbacks)
//! whenever artifacts exist for the block size. Skips (with a loud
//! message) when artifacts are missing so `cargo test` stays runnable
//! before `make artifacts`.
//!
//! The `stub_fallback` module runs in the default (no `pjrt` feature)
//! build and pins the other half of the fallback policy: an engine that
//! can serve nothing falls back to bit-identical native execution while
//! counting every miss.

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::isomap;
use isospark::data::swiss_roll;
use isospark::engine::metrics::OffloadOp;
use isospark::kernels;
use isospark::linalg::Matrix;
use isospark::runtime::PjrtEngine;
use isospark::util::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine() -> Option<PjrtEngine> {
    match PjrtEngine::load(&artifacts_dir()) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP runtime_equivalence: {err:#} (run `make artifacts`)");
            None
        }
    }
}

fn random(r: usize, c: usize, seed: u64, lo: f64, hi: f64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut m = Matrix::zeros(r, c);
    for i in 0..r {
        for j in 0..c {
            m[(i, j)] = rng.range(lo, hi);
        }
    }
    m
}

/// Random graph block with infinities (the APSP no-edge marker).
fn random_graph(b: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut m = Matrix::full(b, b, f64::INFINITY);
    for i in 0..b {
        m[(i, i)] = 0.0;
        for j in 0..b {
            if i != j && rng.f64() < 0.4 {
                m[(i, j)] = rng.range(0.1, 5.0);
            }
        }
    }
    m
}

/// Rectangular min-plus operand with infinities.
fn random_weights(r: usize, c: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut m = Matrix::zeros(r, c);
    for i in 0..r {
        for j in 0..c {
            m[(i, j)] = if rng.f64() < 0.25 { f64::INFINITY } else { rng.range(0.1, 5.0) };
        }
    }
    m
}

fn assert_close_inf(got: &Matrix, want: &Matrix, tol: f64, what: &str) {
    assert_eq!((got.nrows(), got.ncols()), (want.nrows(), want.ncols()), "{what}: shape");
    for (i, (x, y)) in got.as_slice().iter().zip(want.as_slice()).enumerate() {
        if x.is_infinite() || y.is_infinite() {
            assert!(x.is_infinite() && y.is_infinite(), "{what}: entry {i}: {x} vs {y}");
        } else {
            assert!((x - y).abs() < tol, "{what}: entry {i}: {x} vs {y}");
        }
    }
}

#[test]
fn minplus_matches_native() {
    let Some(rt) = engine() else { return };
    for b in [32usize, 64, 128] {
        let a = random_graph(b, 1);
        let c = random_graph(b, 2);
        let got = rt.minplus(&a, &c).expect("minplus artifact");
        let want = kernels::minplus::minplus(&a, &c);
        assert_close_inf(&got, &want, 1e-12, &format!("minplus b={b}"));
    }
}

#[test]
fn fw_matches_native() {
    let Some(rt) = engine() else { return };
    for b in [32usize, 64] {
        let g = random_graph(b, 3);
        let got = rt.floyd_warshall(&g).expect("fw artifact");
        let want = kernels::floyd_warshall::floyd_warshall(&g);
        assert_close_inf(&got, &want, 1e-10, &format!("fw b={b}"));
    }
}

#[test]
fn dist_matches_native() {
    let Some(rt) = engine() else { return };
    for (b, dim) in [(32usize, 3usize), (64, 784), (128, 16)] {
        let xi = random(b, dim, 5, -3.0, 3.0);
        let xj = random(b, dim, 6, -3.0, 3.0);
        let got = rt.dist_block(&xi, &xj).expect("dist artifact");
        let want = kernels::sqdist::dist_block(&xi, &xj);
        assert!(got.max_abs_diff(&want) < 1e-9, "b={b} dim={dim}");
    }
}

#[test]
fn center_matches_native() {
    let Some(rt) = engine() else { return };
    let b = 64;
    let blk = random(b, b, 7, 0.0, 50.0);
    let mu_r: Vec<f64> = (0..b).map(|i| i as f64 * 0.1).collect();
    let mu_c: Vec<f64> = (0..b).map(|i| 3.0 - i as f64 * 0.05).collect();
    let got = rt.center_block(&blk, &mu_r, &mu_c, 1.75).expect("center artifact");
    let mut want = blk.clone();
    kernels::centering::center_block(&mut want, &mu_r, &mu_c, 1.75);
    assert!(got.max_abs_diff(&want) < 1e-12);
}

#[test]
fn gemm_matches_native_with_padding() {
    let Some(rt) = engine() else { return };
    let b = 64;
    let a = random(b, b, 8, -2.0, 2.0);
    for d in [2usize, 3, 8] {
        let q = random(b, d, 9, -1.0, 1.0);
        let got = rt.gemm(&a, &q).expect("gemm artifact");
        let mut want = Matrix::zeros(b, d);
        kernels::matvec::gemm_acc(&a, &q, &mut want);
        assert!(got.max_abs_diff(&want) < 1e-11, "d={d}");

        let got_t = rt.gemm_t(&a, &q).expect("gemmt artifact");
        let mut want_t = Matrix::zeros(b, d);
        kernels::matvec::gemm_t_acc(&a, &q, &mut want_t);
        assert!(got_t.max_abs_diff(&want_t) < 1e-11, "t d={d}");
    }
}

// ---- Ragged (`b ∤ n`) shapes: the shape-polymorphic padded path. ----

#[test]
fn ragged_minplus_padded_matches_native() {
    let Some(rt) = engine() else { return };
    // Square ragged, and the rectangular Phase-2/3 operand mixes the APSP
    // coordinator issues against a ragged tail (pivot p×p with p < b,
    // row/column segments p×c and r×p).
    for (m, k, n) in [(33usize, 33usize, 33usize), (17, 33, 9), (33, 17, 64), (64, 33, 64)] {
        let a = random_weights(m, k, (m * k + n) as u64);
        let b = random_weights(k, n, (m * k + n) as u64 + 7);
        let got = rt.minplus(&a, &b).unwrap_or_else(|e| panic!("m={m} k={k} n={n}: {e}"));
        let want = kernels::minplus::minplus(&a, &b);
        assert_close_inf(&got, &want, 1e-12, &format!("ragged minplus {m}x{k}x{n}"));
    }
    // Padded executions must be recorded as padded hits, not misses.
    let snap = rt.stats().op_snapshot(OffloadOp::Minplus);
    assert!(snap.padded >= 4, "expected padded hits, got {snap:?}");
    assert_eq!(snap.missed, 0, "ragged shapes must not fall off the PJRT path: {snap:?}");
}

#[test]
fn ragged_fw_padded_matches_native() {
    let Some(rt) = engine() else { return };
    for b in [5usize, 33, 100] {
        let g = random_graph(b, b as u64);
        let got = rt.floyd_warshall(&g).unwrap_or_else(|e| panic!("b={b}: {e}"));
        let want = kernels::floyd_warshall::floyd_warshall(&g);
        assert_close_inf(&got, &want, 1e-10, &format!("ragged fw b={b}"));
    }
    assert_eq!(rt.stats().op_snapshot(OffloadOp::Fw).missed, 0);
}

#[test]
fn ragged_dist_padded_matches_native() {
    let Some(rt) = engine() else { return };
    // Ragged point counts, rectangular pairs, and a dimensionality (5)
    // that only exists via zero-padding up to the dim=16 artifact.
    for (r, c, dim) in [(33usize, 33usize, 3usize), (10, 27, 3), (20, 20, 5), (70, 33, 16)] {
        let xi = random(r, dim, (r + c) as u64, -3.0, 3.0);
        let xj = random(c, dim, (r + c) as u64 + 3, -3.0, 3.0);
        let got = rt.dist_block(&xi, &xj).unwrap_or_else(|e| panic!("r={r} c={c} dim={dim}: {e}"));
        let want = kernels::sqdist::dist_block(&xi, &xj);
        assert!(got.max_abs_diff(&want) < 1e-9, "r={r} c={c} dim={dim}");
    }
    assert_eq!(rt.stats().op_snapshot(OffloadOp::Dist).missed, 0);
}

#[test]
fn ragged_center_padded_matches_native() {
    let Some(rt) = engine() else { return };
    // Non-square blocks: the UT layout's (I, q-1) blocks are b×r ragged.
    for (r, c) in [(33usize, 33usize), (64, 17), (5, 40)] {
        let blk = random(r, c, (r * c) as u64, 0.0, 50.0);
        let mu_r: Vec<f64> = (0..r).map(|i| i as f64 * 0.1).collect();
        let mu_c: Vec<f64> = (0..c).map(|i| 2.0 - i as f64 * 0.03).collect();
        let got =
            rt.center_block(&blk, &mu_r, &mu_c, 1.25).unwrap_or_else(|e| panic!("{r}x{c}: {e}"));
        let mut want = blk.clone();
        kernels::centering::center_block(&mut want, &mu_r, &mu_c, 1.25);
        assert!(got.max_abs_diff(&want) < 1e-12, "r={r} c={c}");
    }
    assert_eq!(rt.stats().op_snapshot(OffloadOp::Center).missed, 0);
}

#[test]
fn ragged_gemm_padded_matches_native() {
    let Some(rt) = engine() else { return };
    for (r, k, d) in [(33usize, 33usize, 2usize), (58, 58, 3), (17, 33, 8)] {
        let a = random(r, k, (r + k + d) as u64, -2.0, 2.0);
        let q = random(k, d, (r + k + d) as u64 + 5, -1.0, 1.0);
        let got = rt.gemm(&a, &q).unwrap_or_else(|e| panic!("gemm {r}x{k} d={d}: {e}"));
        let mut want = Matrix::zeros(r, d);
        kernels::matvec::gemm_acc(&a, &q, &mut want);
        assert!(got.max_abs_diff(&want) < 1e-11, "gemm r={r} k={k} d={d}");

        let qt = random(r, d, (r + k + d) as u64 + 9, -1.0, 1.0);
        let got_t = rt.gemm_t(&a, &qt).unwrap_or_else(|e| panic!("gemmt {r}x{k} d={d}: {e}"));
        let mut want_t = Matrix::zeros(k, d);
        kernels::matvec::gemm_t_acc(&a, &qt, &mut want_t);
        assert!(got_t.max_abs_diff(&want_t) < 1e-11, "gemmt r={r} k={k} d={d}");
    }
    assert_eq!(rt.stats().op_snapshot(OffloadOp::Gemm).missed, 0);
    assert_eq!(rt.stats().op_snapshot(OffloadOp::Gemmt).missed, 0);
}

#[test]
fn shapes_beyond_every_artifact_miss_cleanly() {
    let Some(rt) = engine() else { return };
    // Padding covers anything up to the largest artifact; beyond that the
    // call must be a *classified* shape miss (counted fallback), never a
    // hard error — and never a silent wrong answer.
    let big = Matrix::zeros(200, 200);
    let err = rt.minplus(&big, &big).unwrap_err();
    assert!(err.is_shape_miss(), "{err}");
    let wide = Matrix::zeros(32, 2000);
    let err = rt.dist_block(&wide, &wide).unwrap_err();
    assert!(err.is_shape_miss(), "{err}");
    assert!(rt.stats().op_snapshot(OffloadOp::Minplus).missed >= 1);
    assert!(rt.stats().op_snapshot(OffloadOp::Dist).missed >= 1);
}

#[test]
fn full_pipeline_pjrt_equals_native() {
    if engine().is_none() {
        return;
    }
    let backend = Backend::pjrt_from_dir(&artifacts_dir()).expect("pjrt backend");
    // n divisible by b so the hot path stays on PJRT end-to-end.
    let ds = swiss_roll::euler_isometric(256, 41);
    let cfg = IsomapConfig { k: 10, d: 2, block: 64, ..Default::default() };
    let cl = ClusterConfig::local();
    let native = isomap::run_with(&ds.points, &cfg, &cl, &Backend::Native).unwrap();
    let pjrt = isomap::run_with(&ds.points, &cfg, &cl, &backend).unwrap();
    assert_eq!(native.embedding.nrows(), pjrt.embedding.nrows());
    let diff = native.embedding.max_abs_diff(&pjrt.embedding);
    assert!(diff < 1e-6, "pjrt vs native embedding max diff = {diff}");
    for (a, b) in native.eigenvalues.iter().zip(&pjrt.eigenvalues) {
        assert!((a - b).abs() / a.abs().max(1.0) < 1e-9);
    }
}

#[test]
fn ragged_pipeline_fully_offloaded() {
    if engine().is_none() {
        return;
    }
    let backend = Backend::pjrt_from_dir(&artifacts_dir()).expect("pjrt backend");
    // b ∤ n: q = 4 blocks with a ragged 58-row tail. Every block op on the
    // ragged row/column must execute through the padded artifact path —
    // offload coverage 100%, zero counted fallbacks.
    let ds = swiss_roll::euler_isometric(250, 43);
    let cfg = IsomapConfig { k: 10, d: 2, block: 64, ..Default::default() };
    let cl = ClusterConfig::local();
    let native = isomap::run_with(&ds.points, &cfg, &cl, &Backend::Native).unwrap();
    let pjrt = isomap::run_with(&ds.points, &cfg, &cl, &backend).unwrap();
    let diff = native.embedding.max_abs_diff(&pjrt.embedding);
    assert!(diff < 1e-6, "ragged pjrt vs native embedding max diff = {diff}");
    let offload = pjrt.offload.expect("pjrt run records offload counters");
    for s in &offload {
        assert_eq!(s.missed, 0, "op {} fell off the PJRT path: {s:?}", s.op.name());
    }
    let padded: u64 = offload.iter().map(|s| s.padded).sum();
    assert!(padded > 0, "ragged run must exercise the padded path: {offload:?}");
}

/// Offline (default-build) half of the fallback policy: a stub engine
/// serves nothing, so every backend call falls back to the native kernel
/// with identical results while the miss counters record each call.
#[cfg(not(feature = "pjrt"))]
mod stub_fallback {
    use super::*;
    use std::path::Path;
    use std::sync::Arc;

    fn stub_backend() -> Backend {
        Backend::Pjrt(Arc::new(PjrtEngine::disconnected(Path::new("artifacts"))))
    }

    #[test]
    fn every_op_counts_one_miss_and_matches_native() {
        let be = stub_backend();
        let native = Backend::Native;

        let xi = random(5, 3, 1, -2.0, 2.0);
        let xj = random(7, 3, 2, -2.0, 2.0);
        assert_eq!(be.dist_block(&xi, &xj).as_slice(), native.dist_block(&xi, &xj).as_slice());
        assert_eq!(be.dist_block_sym(&xi).as_slice(), native.dist_block_sym(&xi).as_slice());

        let a = random_weights(5, 5, 3);
        let b = random_weights(5, 5, 4);
        let mut dst = Matrix::full(5, 5, f64::INFINITY);
        let mut dst_n = dst.clone();
        be.minplus_into(&a, &b, &mut dst);
        native.minplus_into(&a, &b, &mut dst_n);
        assert_eq!(dst.as_slice(), dst_n.as_slice());

        let mut left = b.clone();
        let mut left_n = b.clone();
        be.minplus_left_inplace(&a, &mut left);
        native.minplus_left_inplace(&a, &mut left_n);
        assert_eq!(left.as_slice(), left_n.as_slice());

        let mut right = b.clone();
        let mut right_n = b.clone();
        be.minplus_right_inplace(&a, &mut right);
        native.minplus_right_inplace(&a, &mut right_n);
        assert_eq!(right.as_slice(), right_n.as_slice());

        let mut g = random_graph(6, 5);
        let mut g_n = g.clone();
        be.fw_inplace(&mut g);
        native.fw_inplace(&mut g_n);
        assert_eq!(g.as_slice(), g_n.as_slice());

        let mut blk = random(4, 6, 6, 0.0, 10.0);
        let mut blk_n = blk.clone();
        let mu_r: Vec<f64> = (0..4).map(|i| i as f64).collect();
        let mu_c: Vec<f64> = (0..6).map(|i| i as f64 * 0.5).collect();
        be.center_block(&mut blk, &mu_r, &mu_c, 0.25);
        native.center_block(&mut blk_n, &mu_r, &mu_c, 0.25);
        assert_eq!(blk.as_slice(), blk_n.as_slice());

        let q = random(5, 2, 7, -1.0, 1.0);
        let mut out = Matrix::zeros(5, 2);
        let mut out_n = Matrix::zeros(5, 2);
        be.gemm_acc(&a, &q, &mut out);
        native.gemm_acc(&a, &q, &mut out_n);
        assert_eq!(out.as_slice(), out_n.as_slice());

        let mut out_t = Matrix::zeros(5, 2);
        let mut out_t_n = Matrix::zeros(5, 2);
        be.gemm_t_acc(&a, &q, &mut out_t);
        native.gemm_t_acc(&a, &q, &mut out_t_n);
        assert_eq!(out_t.as_slice(), out_t_n.as_slice());

        // Every call above must be accounted as exactly one miss on its op
        // (dist gets two: dist_block + dist_block_sym route through it).
        let stats = be.offload_stats().unwrap();
        assert_eq!(stats.op_snapshot(OffloadOp::Dist).missed, 2);
        assert_eq!(stats.op_snapshot(OffloadOp::Minplus).missed, 3);
        assert_eq!(stats.op_snapshot(OffloadOp::Fw).missed, 1);
        assert_eq!(stats.op_snapshot(OffloadOp::Center).missed, 1);
        assert_eq!(stats.op_snapshot(OffloadOp::Gemm).missed, 1);
        assert_eq!(stats.op_snapshot(OffloadOp::Gemmt).missed, 1);
        assert_eq!(stats.total_calls(), stats.total_missed(), "stub never offloads");
    }

    #[test]
    fn stub_pipeline_matches_native_and_counts_fallbacks() {
        // Ragged n through the stub-PJRT backend: numerics identical to
        // native, and the run's offload snapshot shows honest zero
        // coverage instead of pretending the offload happened.
        let ds = swiss_roll::euler_isometric(50, 17);
        let cfg = IsomapConfig { k: 6, d: 2, block: 16, ..Default::default() };
        let cl = ClusterConfig::local();
        let be = stub_backend();
        let native = isomap::run_with(&ds.points, &cfg, &cl, &Backend::Native).unwrap();
        let stubbed = isomap::run_with(&ds.points, &cfg, &cl, &be).unwrap();
        for (a, b) in native.embedding.as_slice().iter().zip(stubbed.embedding.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "stub fallback must be bit-identical");
        }
        let offload = stubbed.offload.expect("pjrt-backend run records counters");
        let total: u64 = offload.iter().map(|s| s.total()).sum();
        let missed: u64 = offload.iter().map(|s| s.missed).sum();
        assert!(total > 0, "pipeline must have issued block ops");
        assert_eq!(total, missed, "every stub call is a counted miss");
        for op in [OffloadOp::Dist, OffloadOp::Minplus, OffloadOp::Fw, OffloadOp::Center] {
            let s = offload.iter().find(|s| s.op == op).unwrap();
            assert!(s.missed > 0, "pipeline never exercised {}", op.name());
        }
        assert!(native.offload.is_none());
    }
}
