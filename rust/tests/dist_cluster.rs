//! Multi-process distribution, end to end over real loopback TCP: the
//! driver's `RemoteCluster` against in-process `dist::worker` instances
//! (the same server loop the `isospark worker` subcommand runs).
//!
//! The contract under test:
//!
//! * the embedding is **bit-identical** to the single-process run for 1,
//!   2, and 4 workers — placement, worker count, and transport never
//!   touch output bits;
//! * deterministic fault injection (`--fault-rate`) composes with real
//!   workers and stays bitwise invisible;
//! * a worker that dies mid-stage (connection dropped without a reply)
//!   is declared lost, its tasks are retried on the survivors, and the
//!   run still lands on the identical bits;
//! * losing *every* worker fails the run with stage context, not a
//!   panic or a poisoned lock.

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, GeodesicsMode, IsomapConfig};
use isospark::coordinator::isomap;
use isospark::data::swiss_roll;
use isospark::dist::worker::{self, WorkerHandle, WorkerOptions};
use isospark::linalg::Matrix;

fn sparse_cfg() -> IsomapConfig {
    // 150 points in 32-blocks: q = 5 geodesic panel tasks per run.
    IsomapConfig {
        k: 8,
        d: 2,
        block: 32,
        geodesics: GeodesicsMode::SparseDijkstra,
        ..Default::default()
    }
}

fn spawn_workers(specs: &[WorkerOptions]) -> (Vec<WorkerHandle>, Vec<String>) {
    let handles: Vec<WorkerHandle> = specs
        .iter()
        .map(|opts| worker::spawn("127.0.0.1:0", opts.clone()).expect("spawn worker"))
        .collect();
    let addrs = handles.iter().map(WorkerHandle::addr).collect();
    (handles, addrs)
}

fn dist_cluster(addrs: Vec<String>, fault_rate: f64) -> ClusterConfig {
    ClusterConfig {
        dist_workers: addrs,
        // Generous for CI, tiny against the 60 s default: a dead worker
        // should fail the stage in seconds, not minutes.
        dist_task_timeout_secs: 10.0,
        fault_rate,
        fault_seed: 11,
        parallelism: 2,
        ..ClusterConfig::local()
    }
}

fn run(x: &Matrix, cfg: &IsomapConfig, cluster: &ClusterConfig) -> isomap::IsomapOutput {
    isomap::run_with(x, cfg, cluster, &Backend::Native).expect("pipeline run")
}

fn assert_bits_eq(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.nrows(), a.ncols()), (b.nrows(), b.ncols()), "{what}: shape");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i}: {x} vs {y}");
    }
}

#[test]
fn embedding_is_bit_identical_across_process_counts() {
    let ds = swiss_roll::euler_isometric(150, 23);
    let cfg = sparse_cfg();
    let local = run(&ds.points, &cfg, &ClusterConfig::local());
    assert!(local.dist.is_none(), "single-process run must not report a dist stage");

    for nworkers in [1usize, 2, 4] {
        let (handles, addrs) = spawn_workers(&vec![WorkerOptions::default(); nworkers]);
        let out = run(&ds.points, &cfg, &dist_cluster(addrs, 0.0));
        assert_bits_eq(&out.embedding, &local.embedding, &format!("{nworkers} workers"));

        let report = out.dist.expect("dist run must carry a DistReport");
        assert_eq!(report.workers, nworkers);
        assert_eq!(report.workers_lost, 0, "healthy fleet reported losses");
        assert_eq!(report.tasks, 5, "q = ceil(150/32) panel tasks");
        assert_eq!(report.retries, 0);
        assert!(report.bytes_sent > 0 && report.bytes_received > 0, "{report:?}");
        assert!(report.wall_secs > 0.0, "{report:?}");
        // The measured wall sits next to a nonzero virtual projection of
        // the same stage — the pairing the run report prints.
        assert!(report.virtual_secs > 0.0, "{report:?}");
        assert!(
            out.metrics_table.contains("geo:dist"),
            "no measured dist stage row:\n{}",
            out.metrics_table
        );
        drop(handles);
    }
}

#[test]
fn fault_injection_composes_with_real_workers() {
    // The PR 7 chaos schedule keys on (stage, task, attempt) and is
    // decided on the driver, so the same faults hit the same tasks
    // whether they execute in-process or across TCP — and the output
    // stays bitwise clean.
    let ds = swiss_roll::euler_isometric(150, 23);
    let cfg = sparse_cfg();
    let clean = run(&ds.points, &cfg, &ClusterConfig::local());

    let (handles, addrs) = spawn_workers(&vec![WorkerOptions::default(); 2]);
    let out = run(&ds.points, &cfg, &dist_cluster(addrs, 0.2));
    assert_bits_eq(&out.embedding, &clean.embedding, "fault rate 0.2 over 2 workers");
    assert!(
        out.metrics_table.contains("resilience"),
        "rate 0.2 must record injections:\n{}",
        out.metrics_table
    );
    drop(handles);
}

#[test]
fn dying_worker_mid_stage_recovers_bitwise() {
    let ds = swiss_roll::euler_isometric(150, 23);
    let cfg = sparse_cfg();
    let clean = run(&ds.points, &cfg, &ClusterConfig::local());

    // One worker executes a single task and then drops the connection
    // without replying (simulated kill -9); two stay healthy. Placement
    // is deterministic (SplitMix64 of the task id over the live set), so
    // this is not a coin flip: over 3 workers the 5 panel tasks land as
    // [_, {0,1,3}, {2,4}] — the dying worker sits at index 1, receives
    // tasks 0, 1, 3 pipelined, completes task 0, and dies on task 1.
    let (handles, addrs) = spawn_workers(&[
        WorkerOptions::default(),
        WorkerOptions { die_after_tasks: Some(1), ..Default::default() },
        WorkerOptions::default(),
    ]);
    let out = run(&ds.points, &cfg, &dist_cluster(addrs, 0.0));
    assert_bits_eq(&out.embedding, &clean.embedding, "one worker lost mid-stage");

    let report = out.dist.expect("dist report");
    assert!(report.workers_lost >= 1, "the dying worker was never declared lost: {report:?}");
    assert!(report.retries >= 1, "its tasks were never requeued: {report:?}");
    drop(handles);
}

#[test]
fn losing_every_worker_fails_with_stage_context() {
    let ds = swiss_roll::euler_isometric(150, 23);
    let cfg = sparse_cfg();

    // The only worker dies before finishing its first task: after the
    // loss there is nowhere left to retry, and the run must fail with a
    // typed error naming the stage — never a panic or a poisoned lock.
    let (handles, addrs) =
        spawn_workers(&[WorkerOptions { die_after_tasks: Some(0), ..Default::default() }]);
    let err = isomap::run_with(&ds.points, &cfg, &dist_cluster(addrs, 0.0), &Backend::Native)
        .expect_err("a fully dead fleet cannot complete the stage");
    let msg = format!("{err:#}");
    assert!(msg.contains("geo:dijkstra"), "stage context lost: {msg}");
    assert!(msg.contains("workers lost"), "loss context lost: {msg}");
    drop(handles);
}

#[test]
fn dist_mode_requires_the_sparse_geodesics_path() {
    let ds = swiss_roll::euler_isometric(96, 7);
    let cfg = IsomapConfig { k: 8, d: 2, block: 32, ..Default::default() };
    let (handles, addrs) = spawn_workers(&[WorkerOptions::default()]);
    let err = isomap::run_with(&ds.points, &cfg, &dist_cluster(addrs, 0.0), &Backend::Native)
        .expect_err("dense geodesics has no remote task vocabulary");
    assert!(format!("{err:#}").contains("sparse-dijkstra"), "{err:#}");
    drop(handles);
}
