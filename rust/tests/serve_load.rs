//! Serve tier under load, end to end over real sockets:
//!
//! * admission control: a full accept queue sheds with 503 (and the soft
//!   zone with 429), always carrying `Retry-After`, while every *accepted*
//!   embed stays bit-identical to in-process `map_points`;
//! * the adaptive micro-batch cap observably moves under latency pressure
//!   and re-converges to the ceiling when the load passes, never leaving
//!   `[floor, ceiling]`;
//! * shutdown mid-load strands no queued embed: every in-flight request
//!   resolves as a correct 200, a 503, or a closed connection — never a
//!   hang;
//! * the pool autoscaler stays inside `threads_min..=threads_max` and
//!   returns to min after the load passes;
//! * the multi-model registry routes by path, hot-reloads one model while
//!   another serves concurrently, and 404s unknown names with context;
//! * the hand-rolled HTTP parser accepts byte-at-a-time delivery split at
//!   every boundary and never panics on malformed or fuzzed input.

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::streaming::StreamingModel;
use isospark::data::swiss_roll;
use isospark::model::FittedModel;
use isospark::serve::registry::Registry;
use isospark::serve::{self, client, ServeConfig};
use isospark::util::json::Json;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

fn fit_model(n: usize, seed: u64) -> FittedModel {
    let ds = swiss_roll::euler_isometric(n, seed);
    let cfg = IsomapConfig { k: 10, d: 2, block: 64, seed, ..Default::default() };
    let m = (n / 6).max(40);
    StreamingModel::fit(&ds.points, &cfg, m, &ClusterConfig::local(), &Backend::Native)
        .expect("fit")
        .into_model()
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("isospark_serve_ld_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn assert_bits_eq(a: &isospark::linalg::Matrix, b: &isospark::linalg::Matrix, what: &str) {
    assert_eq!((a.nrows(), a.ncols()), (b.nrows(), b.ncols()), "{what}: shape");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i}: {x} vs {y}");
    }
}

fn embed_body(pts: &isospark::linalg::Matrix) -> String {
    Json::obj(vec![("points", serve::matrix_to_json(pts))]).to_string()
}

fn embedding_of(body: &str) -> isospark::linalg::Matrix {
    let j = Json::parse(body).expect("embed response is JSON");
    serve::matrix_from_json(j.get("embedding").expect("embedding field")).expect("matrix")
}

fn metric_at<'a>(metrics: &'a Json, path: &[&str]) -> &'a Json {
    let mut cur = metrics;
    for key in path {
        cur = cur.get(key).unwrap_or_else(|| panic!("missing /metrics key {key:?}"));
    }
    cur
}

#[test]
fn zero_capacity_queue_sheds_every_embed_with_retry_after() {
    let model = fit_model(240, 4);
    let fresh = swiss_roll::euler_isometric(8, 91).points;
    let handle = serve::start(
        model,
        None,
        None,
        &ServeConfig { threads: 2, max_queue: 0, ..Default::default() },
    )
    .expect("start");
    let addr = handle.addr();

    let body = embed_body(&fresh);
    let mut conn = client::Conn::connect(&addr).unwrap();
    for round in 0..3 {
        let resp = conn.request_response("POST", "/v1/embed", Some(&body)).unwrap();
        assert_eq!(resp.status, 503, "round {round}: {}", resp.body);
        let ra: u64 = resp
            .header("retry-after")
            .unwrap_or_else(|| panic!("round {round}: shed response lacks Retry-After"))
            .parse()
            .expect("numeric Retry-After");
        assert!((1..=30).contains(&ra), "Retry-After {ra} out of range");
        assert!(resp.body.contains("queue"), "shed body names the queue: {}", resp.body);
    }
    // Non-embed endpoints are never shed: the replica stays observable.
    let (code, health) = client::get_json(&addr, "/healthz").unwrap();
    assert_eq!(code, 200);
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    let (_, metrics) = client::get_json(&addr, "/metrics").unwrap();
    assert!(metric_at(&metrics, &["requests", "shed"]).as_usize().unwrap() >= 3);
    assert!(metric_at(&metrics, &["admission", "shed_503"]).as_usize().unwrap() >= 3);
    assert_eq!(metric_at(&metrics, &["admission", "capacity"]).as_usize(), Some(0));
    handle.shutdown();
}

#[test]
fn overload_sheds_transiently_while_accepted_embeds_stay_bit_identical() {
    let model = fit_model(280, 6);
    let fresh = swiss_roll::euler_isometric(64, 17).points;
    let expected = model.map_points(&fresh).unwrap();
    // A one-deep accept queue under 8 concurrent clients guarantees
    // contention: while the batch executor holds one request, any second
    // concurrent arrival must shed.
    let handle = serve::start(
        model,
        None,
        None,
        &ServeConfig {
            threads: 4,
            max_queue: 1,
            max_batch: 8,
            target_p95_ms: 0.0,
            ..Default::default()
        },
    )
    .expect("start");
    let addr = handle.addr();

    let clients = 8usize;
    let rounds = 30usize;
    let rows = fresh.nrows() / clients;
    let ok_total = AtomicUsize::new(0);
    let shed_total = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for c in 0..clients {
            let addr = addr.clone();
            let (fresh, expected) = (&fresh, &expected);
            let (ok_total, shed_total) = (&ok_total, &shed_total);
            scope.spawn(move || {
                let mut conn = client::Conn::connect(&addr).unwrap();
                let pts = fresh.slice(c * rows, (c + 1) * rows, 0, fresh.ncols());
                let want = expected.slice(c * rows, (c + 1) * rows, 0, expected.ncols());
                let body = embed_body(&pts);
                for round in 0..rounds {
                    let resp =
                        conn.request_response("POST", "/v1/embed", Some(&body)).unwrap();
                    match resp.status {
                        200 => {
                            // The acceptance criterion: accepted-under-
                            // overload output is bitwise what an idle
                            // server (and in-process map_points) returns.
                            let got = embedding_of(&resp.body);
                            assert_bits_eq(&got, &want, &format!("client {c} round {round}"));
                            ok_total.fetch_add(1, Ordering::Relaxed);
                        }
                        429 | 503 => {
                            let ra: u64 = resp
                                .header("retry-after")
                                .expect("shed carries Retry-After")
                                .parse()
                                .expect("numeric Retry-After");
                            assert!(ra >= 1);
                            shed_total.fetch_add(1, Ordering::Relaxed);
                        }
                        other => panic!("unexpected status {other}: {}", resp.body),
                    }
                }
            });
        }
    });
    let (ok, shed) = (ok_total.load(Ordering::Relaxed), shed_total.load(Ordering::Relaxed));
    assert_eq!(ok + shed, clients * rounds, "every request resolved");
    assert!(ok >= 1, "some requests must be served (ok={ok} shed={shed})");
    assert!(shed >= 1, "a one-deep queue under 8 clients must shed (ok={ok} shed={shed})");
    let (_, metrics) = client::get_json(&addr, "/metrics").unwrap();
    assert_eq!(metric_at(&metrics, &["requests", "shed"]).as_usize(), Some(shed));
    handle.shutdown();
}

#[test]
fn adaptive_batch_cap_shrinks_under_pressure_and_reconverges_when_idle() {
    let model = fit_model(260, 12);
    let pool = swiss_roll::euler_isometric(64, 23).points;
    // A 1µs p95 target is unattainable over real sockets, so every loaded
    // control window shrinks the cap; idle windows read p95 = 0 and grow
    // it back — both controller motions become observable.
    let handle = serve::start(
        model,
        None,
        None,
        &ServeConfig {
            threads: 2,
            max_batch: 64,
            batch_min: 1,
            target_p95_ms: 0.001,
            ..Default::default()
        },
    )
    .expect("start");
    let addr = handle.addr();

    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for t in 0..4usize {
            let addr = addr.clone();
            let (pool, stop) = (&pool, &stop);
            scope.spawn(move || {
                let mut conn = client::Conn::connect(&addr).unwrap();
                let pts = pool.slice(t * 8, t * 8 + 8, 0, pool.ncols());
                while !stop.load(Ordering::Relaxed) {
                    client::embed_on(&mut conn, &pts).unwrap();
                }
            });
        }

        // Under load: poll until the controller has shrunk the cap.
        let deadline = Instant::now() + Duration::from_secs(20);
        let shrunk = loop {
            let (_, metrics) = client::get_json(&addr, "/metrics").unwrap();
            let cap = metric_at(&metrics, &["adaptive_batch", "cap"]).as_usize().unwrap();
            let shrinks =
                metric_at(&metrics, &["adaptive_batch", "shrinks"]).as_usize().unwrap();
            assert!((1..=64).contains(&cap), "cap {cap} escaped [floor, ceiling]");
            if shrinks >= 1 && cap < 64 {
                break cap;
            }
            assert!(Instant::now() < deadline, "cap never shrank under load (cap {cap})");
            std::thread::sleep(Duration::from_millis(50));
        };
        assert!(shrunk < 64);
        stop.store(true, Ordering::Relaxed);
    });

    // Idle: empty windows read p95 = 0, so the cap doubles back up to the
    // ceiling — the re-convergence path after the spike passes.
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let (_, metrics) = client::get_json(&addr, "/metrics").unwrap();
        let cap = metric_at(&metrics, &["adaptive_batch", "cap"]).as_usize().unwrap();
        assert!((1..=64).contains(&cap), "cap {cap} escaped [floor, ceiling]");
        if cap == 64 {
            let grows = metric_at(&metrics, &["adaptive_batch", "grows"]).as_usize().unwrap();
            assert!(grows >= 1, "re-convergence must be counted as grows");
            break;
        }
        assert!(Instant::now() < deadline, "cap never re-converged to the ceiling (cap {cap})");
        std::thread::sleep(Duration::from_millis(50));
    }
    handle.shutdown();
}

#[test]
fn shutdown_mid_load_strands_no_embed() {
    let model = fit_model(260, 8);
    let fresh = swiss_roll::euler_isometric(16, 41).points;
    let expected = model.map_points(&fresh).unwrap();
    let handle = serve::start(
        model,
        None,
        None,
        &ServeConfig { threads: 2, ..Default::default() },
    )
    .expect("start");
    let addr = handle.addr();

    let ok_total = AtomicUsize::new(0);
    let body = embed_body(&fresh);
    std::thread::scope(|scope| {
        for _ in 0..6usize {
            let addr = addr.clone();
            let (body, expected, ok_total) = (&body, &expected, &ok_total);
            scope.spawn(move || {
                let mut conn = client::Conn::connect(&addr).unwrap();
                loop {
                    match conn.request_response("POST", "/v1/embed", Some(body)) {
                        // A request the server accepted must complete with
                        // the right bits, even racing shutdown.
                        Ok(resp) if resp.status == 200 => {
                            assert_bits_eq(&embedding_of(&resp.body), expected, "during shutdown");
                            ok_total.fetch_add(1, Ordering::Relaxed);
                        }
                        // Shed at the stop gate: also a clean resolution.
                        Ok(resp) if resp.status == 503 => break,
                        Ok(resp) => panic!("unexpected status {}: {}", resp.status, resp.body),
                        // Connection torn down by the stopping server.
                        Err(_) => break,
                    }
                }
            });
        }
        std::thread::sleep(Duration::from_millis(300));
        // The scope only exits if every client thread terminates — i.e. no
        // embed was left stranded waiting on a response that never comes.
        handle.shutdown();
    });
    assert!(ok_total.load(Ordering::Relaxed) >= 1, "load ran before shutdown");
}

#[test]
fn pool_autoscaler_stays_in_bounds_and_returns_to_min() {
    let model = fit_model(260, 14);
    let pool = swiss_roll::euler_isometric(64, 29).points;
    let handle = serve::start(
        model,
        None,
        None,
        &ServeConfig { threads_min: 1, threads_max: 4, ..Default::default() },
    )
    .expect("start");
    let addr = handle.addr();
    assert_eq!(handle.active_workers(), 1, "starts at threads_min");

    let stop = AtomicBool::new(false);
    let mut max_seen = 0usize;
    std::thread::scope(|scope| {
        for t in 0..8usize {
            let addr = addr.clone();
            let (pool, stop) = (&pool, &stop);
            scope.spawn(move || {
                let mut conn = client::Conn::connect(&addr).unwrap();
                let pts = pool.slice(t * 4, t * 4 + 4, 0, pool.ncols());
                while !stop.load(Ordering::Relaxed) {
                    client::embed_on(&mut conn, &pts).unwrap();
                }
            });
        }
        // Sample the pool size while 8 connections contend for it.
        let until = Instant::now() + Duration::from_secs(4);
        while Instant::now() < until {
            let active = handle.active_workers();
            assert!(
                (1..=4).contains(&active),
                "active workers {active} escaped threads_min..=threads_max"
            );
            max_seen = max_seen.max(active);
            std::thread::sleep(Duration::from_millis(25));
        }
        stop.store(true, Ordering::Relaxed);
    });
    assert!(max_seen >= 2, "8 contending connections must scale the pool up (saw {max_seen})");
    let (_, metrics) = client::get_json(&addr, "/metrics").unwrap();
    assert!(metric_at(&metrics, &["autoscale", "scale_ups"]).as_usize().unwrap() >= 1);
    assert_eq!(metric_at(&metrics, &["autoscale", "min"]).as_usize(), Some(1));
    assert_eq!(metric_at(&metrics, &["autoscale", "max"]).as_usize(), Some(4));

    // Idle: retire tickets drain the pool back to min (each step needs
    // DOWN_COOLDOWN consecutive idle control intervals, so be generous).
    let deadline = Instant::now() + Duration::from_secs(40);
    loop {
        let active = handle.active_workers();
        assert!((1..=4).contains(&active), "active workers {active} out of bounds going down");
        if active == 1 {
            break;
        }
        assert!(Instant::now() < deadline, "pool never returned to min (active {active})");
        std::thread::sleep(Duration::from_millis(200));
    }
    handle.shutdown();
}

#[test]
fn registry_routes_reloads_and_isolates_models() {
    let model_a = fit_model(260, 31);
    let model_b = fit_model(260, 32);
    let model_c = fit_model(260, 33);
    let dir_b = tmp_dir("reg_b");
    let dir_c = tmp_dir("reg_c");
    model_b.save(&dir_b).unwrap();
    model_c.save(&dir_c).unwrap();
    let fresh = swiss_roll::euler_isometric(12, 61).points;
    let expect_a = model_a.map_points(&fresh).unwrap();
    let expect_b = model_b.map_points(&fresh).unwrap();
    let expect_c = model_c.map_points(&fresh).unwrap();
    assert!(expect_b.max_abs_diff(&expect_c) > 0.0, "fixture models indistinguishable");

    let registry = Registry::from_entries(vec![
        ("alpha".to_string(), model_a, None),
        ("beta".to_string(), FittedModel::load(&dir_b).unwrap(), Some(dir_b.clone())),
    ])
    .unwrap();
    let handle = serve::start_registry(
        registry,
        None,
        &ServeConfig { threads: 4, ..Default::default() },
    )
    .expect("start");
    let addr = handle.addr();

    // Both models route by path; the legacy path aliases the first entry.
    assert_bits_eq(&client::embed_model(&addr, "alpha", &fresh).unwrap(), &expect_a, "alpha");
    assert_bits_eq(&client::embed_model(&addr, "beta", &fresh).unwrap(), &expect_b, "beta");
    assert_bits_eq(&client::embed(&addr, &fresh).unwrap(), &expect_a, "legacy → default");

    let (code, models) = client::get_json(&addr, "/v1/models").unwrap();
    assert_eq!(code, 200);
    let names: Vec<&str> = models
        .get("models")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(Json::as_str)
        .collect();
    assert_eq!(names, vec!["alpha", "beta"]);

    // Unknown model: 404 naming what does exist.
    let mut conn = client::Conn::connect(&addr).unwrap();
    let resp =
        conn.request_response("POST", "/v1/models/nope/embed", Some(&embed_body(&fresh))).unwrap();
    assert_eq!(resp.status, 404, "{}", resp.body);
    assert!(resp.body.contains("available"), "404 lists alternatives: {}", resp.body);
    assert!(resp.body.contains("alpha"), "{}", resp.body);
    // Wrong method on a known per-model action: 405, not 404.
    let resp = conn.request_response("GET", "/v1/models/alpha/embed", None).unwrap();
    assert_eq!(resp.status, 405, "{}", resp.body);

    // Hot-reload beta → model_c while alpha serves concurrently: alpha's
    // bits never waver, beta switches over atomically.
    std::thread::scope(|scope| {
        let alpha_addr = addr.clone();
        let (fresh_ref, expect_a_ref) = (&fresh, &expect_a);
        let hammer = scope.spawn(move || {
            let mut conn = client::Conn::connect(&alpha_addr).unwrap();
            for round in 0..40 {
                let got =
                    client::embed_path_on(&mut conn, "/v1/models/alpha/embed", fresh_ref).unwrap();
                assert_bits_eq(&got, expect_a_ref, &format!("alpha during reload, round {round}"));
            }
        });
        let body = Json::obj(vec![("path", Json::str(dir_c.to_str().unwrap()))]);
        let (code, resp) = client::post_json(&addr, "/v1/models/beta/reload", &body).unwrap();
        assert_eq!(code, 200, "{resp}");
        hammer.join().unwrap();
    });
    assert_bits_eq(&client::embed_model(&addr, "beta", &fresh).unwrap(), &expect_c, "beta after");
    assert_bits_eq(&client::embed_model(&addr, "alpha", &fresh).unwrap(), &expect_a, "alpha after");

    // Failed reload: 400 with context, beta keeps serving model_c.
    let bad = Json::obj(vec![("path", Json::str("/nonexistent/model/dir"))]);
    let (code, resp) = client::post_json(&addr, "/v1/models/beta/reload", &bad).unwrap();
    assert_eq!(code, 400, "{resp}");
    assert!(format!("{resp}").contains("keeping current model"), "{resp}");
    assert_bits_eq(
        &client::embed_model(&addr, "beta", &fresh).unwrap(),
        &expect_c,
        "beta after failed reload",
    );
    // Alpha was registered without a source path: pathless reload errors.
    let (code, resp) =
        client::post_json(&addr, "/v1/models/alpha/reload", &Json::obj(vec![])).unwrap();
    assert_eq!(code, 400, "{resp}");
    assert!(format!("{resp}").contains("pass a path"), "{resp}");

    // Per-model observability: the name-scoped endpoint and the /metrics
    // "models" section both account per-model traffic.
    let (code, alpha_m) = client::get_json(&addr, "/v1/models/alpha/metrics").unwrap();
    assert_eq!(code, 200);
    let alpha_embeds =
        metric_at(&alpha_m, &["metrics", "embeds"]).as_usize().unwrap();
    assert!(alpha_embeds >= 40, "alpha embeds {alpha_embeds}");
    let (_, metrics) = client::get_json(&addr, "/metrics").unwrap();
    assert!(metric_at(&metrics, &["models", "beta", "reloads_ok"]).as_usize().unwrap() >= 1);
    assert!(metric_at(&metrics, &["models", "beta", "reloads_failed"]).as_usize().unwrap() >= 1);
    handle.shutdown();
}

#[test]
fn registry_rejects_invalid_and_duplicate_names() {
    let model = fit_model(240, 51);
    let err = Registry::from_entries(vec![("has space".to_string(), model.clone(), None)])
        .unwrap_err();
    assert!(err.contains("invalid model name"), "{err}");
    let err = Registry::from_entries(vec![
        ("twin".to_string(), model.clone(), None),
        ("twin".to_string(), model.clone(), None),
    ])
    .unwrap_err();
    assert!(err.contains("duplicate"), "{err}");
    let err = Registry::from_entries(vec![]).unwrap_err();
    assert!(err.contains("at least one"), "{err}");
}

/// Property/fuzz tests for the hand-rolled HTTP parser, mirroring the
/// byte-at-a-time discipline of `dist/proto.rs`: framing must be
/// insensitive to how the network fragments the stream, and malformed
/// input must yield typed errors, never panics.
mod http_fuzz {
    use isospark::serve::http;

    fn canonical_requests() -> Vec<Vec<u8>> {
        let post = |path: &str, body: &str| {
            format!(
                "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .into_bytes()
        };
        vec![
            b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n".to_vec(),
            b"GET /v1/models HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n".to_vec(),
            post("/v1/embed", "{\"points\": [[1.0, 2.0, 3.0]]}"),
            post("/v1/models/alpha/embed", "{\"points\": [[0.5, -1.25, 3e-7]]}"),
            post("/v1/models/m-1.v2/reload", "{\"path\": \"/tmp/m\"}"),
        ]
    }

    #[test]
    fn every_split_point_parses_identically() {
        for full in canonical_requests() {
            let (whole, used) = http::try_parse(&full).expect("canonical parses").expect("complete");
            assert_eq!(used, full.len());
            for cut in 0..full.len() {
                // Any strict prefix is incomplete — never an error, never
                // a truncated parse.
                assert!(
                    matches!(http::try_parse(&full[..cut]), Ok(None)),
                    "prefix of {} bytes misparsed (path {})",
                    cut,
                    whole.path
                );
            }
        }
    }

    #[test]
    fn byte_at_a_time_delivery_matches_one_shot() {
        for full in canonical_requests() {
            let (whole, _) = http::try_parse(&full).unwrap().unwrap();
            let mut buf = Vec::new();
            let mut parsed = None;
            for (i, &b) in full.iter().enumerate() {
                buf.push(b);
                if let Some((req, used)) = http::try_parse(&buf).unwrap() {
                    assert_eq!(i, full.len() - 1, "parsed before the final byte of {}", whole.path);
                    assert_eq!(used, full.len());
                    parsed = Some(req);
                }
            }
            let req = parsed.expect("full delivery parses");
            assert_eq!(req.method, whole.method);
            assert_eq!(req.path, whole.path);
            assert_eq!(req.body, whole.body);
        }
    }

    #[test]
    fn pipelined_stream_parses_in_order() {
        let reqs = canonical_requests();
        let mut stream: Vec<u8> = Vec::new();
        for r in &reqs {
            stream.extend_from_slice(r);
        }
        let mut seen = Vec::new();
        while !stream.is_empty() {
            let (req, used) = http::try_parse(&stream).unwrap().expect("next pipelined request");
            seen.push(req.path.clone());
            stream.drain(..used);
        }
        let want: Vec<String> = reqs
            .iter()
            .map(|r| {
                let (req, _) = http::try_parse(r).unwrap().unwrap();
                req.path
            })
            .collect();
        assert_eq!(seen, want);
    }

    /// Deterministic xorshift64* generator — no rand crate in this repo.
    struct Lcg(u64);
    impl Lcg {
        fn next_u64(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x.wrapping_mul(0x2545_F491_4F6C_DD1D)
        }
    }

    #[test]
    fn fuzzed_input_yields_typed_errors_never_panics() {
        let mut rng = Lcg(0x9E37_79B9_7F4A_7C15);
        // Pure garbage of every size class.
        for _ in 0..2_000 {
            let len = (rng.next_u64() % 600) as usize;
            let buf: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect();
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _ = http::try_parse(&buf);
            }));
            assert!(r.is_ok(), "parser panicked on {len}-byte garbage");
        }
        // Mutations of valid requests: flip a few bytes, parse, never panic.
        for full in canonical_requests() {
            for _ in 0..400 {
                let mut buf = full.clone();
                for _ in 0..=(rng.next_u64() % 3) {
                    let i = (rng.next_u64() as usize) % buf.len();
                    buf[i] = (rng.next_u64() & 0xff) as u8;
                }
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let _ = http::try_parse(&buf);
                }));
                assert!(r.is_ok(), "parser panicked on mutated request");
            }
        }
        // Oversized inputs stay typed errors.
        let huge = vec![b'H'; http::MAX_HEAD_BYTES + 64];
        assert!(http::try_parse(&huge).is_err());
        let body_bomb =
            format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", http::MAX_BODY_BYTES + 1);
        assert!(http::try_parse(body_bomb.as_bytes()).is_err());
    }
}
