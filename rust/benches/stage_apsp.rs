//! Bench: the 3-phase blocked Floyd–Warshall APSP — the paper's dominant
//! O(n³) stage — on the real engine, plus the raw min-plus kernel it is
//! built from, plus the checkpoint-cadence ablation (§III-B: "every 10
//! iterations performs best").
//!
//! Run: `cargo bench --bench stage_apsp`

use isospark::backend::Backend;
use isospark::bench::Bencher;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::{apsp, blocks_from_dense, knn, num_blocks};
use isospark::data::swiss_roll;
use isospark::engine::partitioner::UpperTriangularPartitioner;
use isospark::engine::SparkContext;
use isospark::kernels::{matvec, minplus};
use isospark::linalg::Matrix;
use isospark::util::json::Json;
use isospark::util::Rng;
use std::sync::Arc;

/// Pre-tiling `minplus_into` (the PR-1 i-k-j loop nest that re-streams
/// `dst`'s row for every `k`) — kept bench-local as the reference baseline
/// the register-blocked kernel is measured against.
fn minplus_into_ref(a: &Matrix, b: &Matrix, dst: &mut Matrix) {
    let (m, kk) = (a.nrows(), a.ncols());
    for i in 0..m {
        let arow = a.row(i);
        for k in 0..kk {
            let aik = arow[k];
            if !aik.is_finite() {
                continue;
            }
            let brow = b.row(k);
            let drow = dst.row_mut(i);
            for (d, &bv) in drow.iter_mut().zip(brow) {
                let cand = aik + bv;
                *d = if cand < *d { cand } else { *d };
            }
        }
    }
}

/// Pre-tiling wide-`d` `gemm_acc` (accumulates straight into `out`'s row
/// per `k`), bench-local baseline for the tiled eigen-stage product.
fn gemm_acc_ref(a: &Matrix, q: &Matrix, out: &mut Matrix) {
    for i in 0..a.nrows() {
        let arow = a.row(i);
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let qrow = q.row(k);
            let orow = out.row_mut(i);
            for (o, &x) in orow.iter_mut().zip(qrow) {
                *o += aik * x;
            }
        }
    }
}

fn dense_block(b: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut m = Matrix::zeros(b, b);
    for i in 0..b {
        for j in 0..b {
            m[(i, j)] = rng.range(0.1, 10.0);
        }
    }
    m
}

fn random_graph(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut g = Matrix::full(n, n, f64::INFINITY);
    for i in 0..n {
        g[(i, i)] = 0.0;
    }
    // Ring + random chords keeps it connected and FW-nontrivial.
    for i in 0..n {
        let j = (i + 1) % n;
        let w = rng.range(0.1, 1.0);
        g[(i, j)] = w;
        g[(j, i)] = w;
        let r = rng.below(n);
        if r != i {
            let w = rng.range(0.5, 3.0);
            g[(i, r)] = g[(i, r)].min(w);
            g[(r, i)] = g[(r, i)].min(w);
        }
    }
    g
}

fn main() {
    let mut bench = Bencher::with(5.0, 5, 1);

    // Raw min-plus kernel (the per-block hot op). Dense finite inputs so
    // the finite-skip fast path cannot shortcut the measurement.
    for b in [64usize, 128, 256] {
        let mut rng = Rng::seed(b as u64);
        let mut dense = || {
            let mut m = Matrix::zeros(b, b);
            for i in 0..b {
                for j in 0..b {
                    m[(i, j)] = rng.range(0.1, 10.0);
                }
            }
            m
        };
        let a = dense();
        let c = dense();
        let mut dst = Matrix::full(b, b, f64::INFINITY);
        let ops = 2.0 * (b as f64).powi(3);
        let secs = bench.case(&format!("minplus:native:b{b}"), || {
            minplus::minplus_into(&a, &c, &mut dst);
        });
        bench.report_value(&format!("minplus:native:b{b}:gflops"), ops / secs / 1e9, "Gop/s");
    }

    // Kernel throughput: register-blocked suite vs the bench-local
    // pre-tiling references, in Gop/s, written to BENCH_kernels.json so
    // every landed PR leaves a comparable kernel-level perf record.
    println!("\n== kernel throughput: tiled vs pre-tiling reference ==");
    let mut kernel_cases: Vec<Json> = Vec::new();
    for b in [64usize, 128, 256] {
        let a = dense_block(b, b as u64 + 1);
        let c = dense_block(b, b as u64 + 2);
        let mut dst = Matrix::full(b, b, f64::INFINITY);
        let mut dst_ref = Matrix::full(b, b, f64::INFINITY);
        let ops = 2.0 * (b as f64).powi(3);
        let tiled = bench.case(&format!("minplus:tiled:b{b}"), || {
            minplus::minplus_into(&a, &c, &mut dst);
        });
        let base = bench.case(&format!("minplus:ref:b{b}"), || {
            minplus_into_ref(&a, &c, &mut dst_ref);
        });
        assert_eq!(dst.as_slice(), dst_ref.as_slice(), "tiled min-plus must be bit-identical");
        bench.report_value(&format!("minplus:tiled_speedup:b{b}"), base / tiled, "x");
        kernel_cases.push(Json::obj(vec![
            ("kernel", Json::str("minplus_into")),
            ("b", Json::num(b as f64)),
            ("tiled_secs", Json::num(tiled)),
            ("ref_secs", Json::num(base)),
            ("tiled_gops", Json::num(ops / tiled / 1e9)),
            ("ref_gops", Json::num(ops / base / 1e9)),
            ("speedup", Json::num(base / tiled)),
        ]));
    }
    {
        // Eigen-stage product at a wide d (exercises the tiled gemm path).
        let (b, d) = (256usize, 16usize);
        let a = dense_block(b, 11);
        let q = dense_block(b, 12).slice(0, b, 0, d);
        let mut out = Matrix::zeros(b, d);
        let mut out_ref = Matrix::zeros(b, d);
        let ops = 2.0 * (b as f64) * (b as f64) * (d as f64);
        let tiled = bench.case(&format!("gemm_acc:tiled:b{b}:d{d}"), || {
            matvec::gemm_acc(&a, &q, &mut out);
        });
        let base = bench.case(&format!("gemm_acc:ref:b{b}:d{d}"), || {
            gemm_acc_ref(&a, &q, &mut out_ref);
        });
        bench.report_value(&format!("gemm_acc:tiled_speedup:b{b}:d{d}"), base / tiled, "x");
        kernel_cases.push(Json::obj(vec![
            ("kernel", Json::str("gemm_acc")),
            ("b", Json::num(b as f64)),
            ("d", Json::num(d as f64)),
            ("tiled_secs", Json::num(tiled)),
            ("ref_secs", Json::num(base)),
            ("tiled_gops", Json::num(ops / tiled / 1e9)),
            ("ref_gops", Json::num(ops / base / 1e9)),
            ("speedup", Json::num(base / tiled)),
        ]));
    }
    isospark::bench::write_kernel_section("BENCH_kernels.json", "stage_apsp", kernel_cases);
    println!("(kernel throughput written to BENCH_kernels.json)");

    // Full APSP through the engine.
    let n = 1024;
    for b in [128usize, 256] {
        let g = random_graph(n, 3);
        let q = num_blocks(n, b);
        let cfg = IsomapConfig { block: b, ..Default::default() };
        bench.case(&format!("apsp:engine:n{n}:b{b}"), || {
            let ctx = SparkContext::new(ClusterConfig::local());
            let part = Arc::new(UpperTriangularPartitioner::new(q, q))
                as Arc<dyn isospark::engine::Partitioner>;
            let rdd = ctx.parallelize("g", blocks_from_dense(&g, b), part);
            let out = apsp::solve(rdd, q, &cfg, &Backend::Native).unwrap();
            assert_eq!(out.len(), q * (q + 1) / 2);
        });
    }

    // Multi-core block executor: sequential (parallelism = 1) vs one
    // worker per core on the same APSP workload. Numerics are bit-identical
    // (see tests/determinism_parallel.rs); only wall-clock moves. Stage
    // wall-times land in BENCH_apsp.json so future PRs have a perf
    // trajectory to compare against.
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    println!("\n== multi-core block executor (APSP wall-clock, {cores} cores) ==");
    let mut scaling_cases: Vec<Json> = Vec::new();
    for n in [512usize, 1024, 2048] {
        let b = 256usize;
        let g = random_graph(n, 7);
        let q = num_blocks(n, b);
        let cfg = IsomapConfig { block: b, ..Default::default() };
        let mut wall = [0.0f64; 2];
        for (slot, threads) in [(0usize, 1usize), (1, cores)] {
            // warmup = 1 so the first-touch page-fault/allocator cost does
            // not land on the sequential case and bias the speedup record.
            let mut run = Bencher::with(12.0, 2, 1);
            wall[slot] = run.case(&format!("apsp:engine:n{n}:b{b}:threads{threads}"), || {
                let ctx = SparkContext::new(ClusterConfig {
                    parallelism: threads,
                    ..ClusterConfig::local()
                });
                let part = Arc::new(UpperTriangularPartitioner::new(q, q))
                    as Arc<dyn isospark::engine::Partitioner>;
                let rdd = ctx.parallelize("g", blocks_from_dense(&g, b), part);
                let out = apsp::solve(rdd, q, &cfg, &Backend::Native).unwrap();
                assert_eq!(out.len(), q * (q + 1) / 2);
            });
        }
        let speedup = wall[0] / wall[1];
        bench.report_value(&format!("apsp:speedup:n{n}:b{b}:x{cores}threads"), speedup, "x");
        scaling_cases.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("b", Json::num(b as f64)),
            ("seq_secs", Json::num(wall[0])),
            ("par_secs", Json::num(wall[1])),
            ("threads", Json::num(cores as f64)),
            ("speedup", Json::num(speedup)),
        ]));
    }
    let bench_json = Json::obj(vec![
        ("bench", Json::str("stage_apsp".to_string())),
        ("cores", Json::num(cores as f64)),
        ("cases", Json::arr(scaling_cases)),
    ]);
    std::fs::write("BENCH_apsp.json", bench_json.to_string()).ok();
    println!("(stage wall-times written to BENCH_apsp.json)");

    // Dense blocked Floyd–Warshall vs the sparse CSR + pooled multi-source
    // Dijkstra geodesics path, on the *same* kNN graph (swiss-roll,
    // k = 10). Both paths produce the squared-geodesic feature blocks the
    // centering stage consumes; the sparse path never builds the dense
    // APSP RDD. Results land in BENCH_geodesics.json (CI uploads it as the
    // BENCH_geodesics artifact).
    println!("\n== geodesics: dense blocked FW vs sparse CSR Dijkstra ({cores} threads) ==");
    let mut geo_cases: Vec<Json> = Vec::new();
    for n in [512usize, 1024, 2048] {
        let (b, k) = (256usize, 10usize);
        let ds = swiss_roll::euler_isometric(n, 17);
        let cfg = IsomapConfig { k, block: b, ..Default::default() };
        // Lists only: the dense case below reconstructs its graph from the
        // lists, so the blocked graph-fill would be wasted setup work.
        let kl = knn::build_lists(
            &SparkContext::new(ClusterConfig::local()),
            &ds.points,
            &cfg,
            &Backend::Native,
        )
        .unwrap();
        let edges = isospark::graph::CsrGraph::from_knn_lists(&kl.lists).unwrap().num_edges();
        let dense_graph = isospark::baselines::knn_graph_dense(&kl.lists);
        let q = num_blocks(n, b);
        let threaded = || SparkContext::new(ClusterConfig {
            parallelism: cores,
            ..ClusterConfig::local()
        });
        let mut run = Bencher::with(12.0, 2, 1);
        let dense_secs = run.case(&format!("geodesics:dense-fw:n{n}:b{b}"), || {
            let ctx = threaded();
            let part = Arc::new(UpperTriangularPartitioner::new(q, q))
                as Arc<dyn isospark::engine::Partitioner>;
            let rdd = ctx.parallelize("g", blocks_from_dense(&dense_graph, b), part);
            let out = apsp::solve(rdd, q, &cfg, &Backend::Native).unwrap();
            assert_eq!(out.len(), q * (q + 1) / 2);
        });
        let sparse_secs = run.case(&format!("geodesics:sparse-dijkstra:n{n}:b{b}"), || {
            let ctx = threaded();
            let out = apsp::solve_sparse(&ctx, &kl.lists, n, &cfg).unwrap();
            assert_eq!(out.len(), q * (q + 1) / 2);
        });
        if n == 512 {
            // Cross-check once per bench run: both paths must agree on the
            // geodesics to 1e-9 elementwise (mirrors the test suite).
            let ctx = threaded();
            let a = apsp::solve_sparse(&ctx, &kl.lists, n, &cfg).unwrap();
            let sparse = isospark::coordinator::dense_from_blocks(&a, n, b);
            let ctx = threaded();
            let part = Arc::new(UpperTriangularPartitioner::new(q, q))
                as Arc<dyn isospark::engine::Partitioner>;
            let rdd = ctx.parallelize("g", blocks_from_dense(&dense_graph, b), part);
            let a = apsp::solve(rdd, q, &cfg, &Backend::Native).unwrap();
            let dense = isospark::coordinator::dense_from_blocks(&a, n, b);
            for (x, y) in dense.as_slice().iter().zip(sparse.as_slice()) {
                assert!((x.sqrt() - y.sqrt()).abs() <= 1e-9, "{x} vs {y}");
            }
        }
        let speedup = dense_secs / sparse_secs;
        bench.report_value(&format!("geodesics:sparse_speedup:n{n}:b{b}"), speedup, "x");
        geo_cases.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("b", Json::num(b as f64)),
            ("k", Json::num(k as f64)),
            ("csr_arcs", Json::num(edges as f64)),
            ("threads", Json::num(cores as f64)),
            ("dense_fw_secs", Json::num(dense_secs)),
            ("sparse_dijkstra_secs", Json::num(sparse_secs)),
            ("speedup", Json::num(speedup)),
        ]));
    }
    isospark::bench::write_kernel_section(
        "BENCH_geodesics.json",
        "stage_apsp:geodesics",
        geo_cases,
    );
    println!("(dense-vs-sparse geodesics written to BENCH_geodesics.json)");

    // Checkpoint-cadence ablation on a simulated 4-node cluster: virtual
    // time as a function of cadence (0 = never). The paper found 10 best.
    println!("\n== checkpoint cadence ablation (virtual seconds, 4 nodes) ==");
    let ds = swiss_roll::euler_isometric(768, 9);
    for cadence in [0usize, 2, 5, 10, 24] {
        let cfg =
            IsomapConfig { k: 10, block: 32, checkpoint_every: cadence, ..Default::default() };
        let ctx = SparkContext::new(ClusterConfig::paper_testbed(4));
        let kg = knn::build(&ctx, &ds.points, &cfg, &Backend::Native).unwrap();
        let _ = apsp::solve(kg.graph, kg.q, &cfg, &Backend::Native).unwrap();
        bench.report_value(
            &format!("apsp:checkpoint_every_{cadence}:virtual"),
            ctx.virtual_now(),
            "virt-s",
        );
    }

    // The same ablation at *paper scale* (simulated): here the disk cost
    // of a checkpoint is material (G ≈ 23 GB), so very frequent
    // checkpointing stops paying — the cadence optimum moves toward the
    // paper's "every 10".
    println!("\n== checkpoint cadence ablation (paper scale, simulated Swiss75 @ 12 nodes) ==");
    let model = isospark::sim::CostModel::calibrate(256);
    for cadence in [1usize, 2, 5, 10, 25, 0] {
        let w = isospark::sim::Workload {
            checkpoint_every: cadence,
            ..isospark::sim::Workload::new("Swiss75", 75_000, 3, 1500)
        };
        let proj = isospark::sim::project(
            &w,
            &ClusterConfig::paper_testbed(12),
            &model,
        );
        bench.report_value(
            &format!("apsp:sim:checkpoint_every_{cadence}:minutes"),
            proj.total_secs.unwrap() / 60.0,
            "min",
        );
    }

    std::fs::create_dir_all("out").ok();
    std::fs::write("out/stage_apsp.json", bench.json()).ok();
}
