//! Serving-path latency bench: fit a small streaming model, stand up the
//! HTTP server on an ephemeral loopback port, and drive it with the
//! keep-alive load generator under several concurrency/batch shapes.
//! Reports exact client-side p50/p95/p99 latency and QPS per case, plus an
//! in-process `map_points` baseline so the HTTP + micro-batching overhead
//! is visible, and merges everything into `BENCH_serve.json` (same
//! section-merging format as `BENCH_kernels.json`; CI uploads it as the
//! `BENCH_serve` artifact).
//!
//! Run with: `cargo bench --bench serve_latency`

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::streaming::StreamingModel;
use isospark::data::swiss_roll;
use isospark::serve::{self, client, ServeConfig};
use isospark::util::json::Json;
use isospark::util::Stopwatch;

fn main() {
    let n = 400;
    let m = 64;
    let cfg = IsomapConfig { k: 10, d: 2, block: 64, ..Default::default() };
    let ds = swiss_roll::euler_isometric(n, 42);
    println!("fitting serve-bench model: n={n} m={m} k={} d={}", cfg.k, cfg.d);
    let model = StreamingModel::fit(&ds.points, &cfg, m, &ClusterConfig::local(), &Backend::Native)
        .expect("fit")
        .into_model();
    let pool = swiss_roll::euler_isometric(256, 97).points;

    // In-process baseline: the projection itself, no HTTP, no batching.
    let mut cases: Vec<Json> = Vec::new();
    {
        let iters = 2000;
        let sw = Stopwatch::start();
        for i in 0..iters {
            let row = pool.slice(i % pool.nrows(), i % pool.nrows() + 1, 0, pool.ncols());
            std::hint::black_box(model.map_points_with(&row, 1).expect("map"));
        }
        let mean_us = sw.secs() / iters as f64 * 1e6;
        println!("{:<44} {:>10.1} µs/point (in-process)", "inproc:map_points:1pt", mean_us);
        cases.push(Json::obj(vec![
            ("name", Json::str("inproc_map_points_1pt")),
            ("requests", Json::num(iters as f64)),
            ("mean_us", Json::num(mean_us)),
        ]));
    }

    let handle = serve::start(model, None, None, &ServeConfig { threads: 4, ..Default::default() })
        .expect("start server");
    let addr = handle.addr();
    println!("loopback server on {addr}");

    // (name, client connections, requests per client, points per request)
    let shapes = [
        ("serve_1pt_1conn", 1, 400, 1),
        ("serve_1pt_8conn", 8, 100, 1),
        ("serve_16pt_4conn", 4, 100, 16),
    ];
    for (name, clients, reqs, ppr) in shapes {
        let rep = client::loopback_load(&addr, clients, reqs, ppr, &pool).expect("load run");
        println!(
            "{name:<44} p50 {:>8.1} µs | p95 {:>8.1} µs | p99 {:>8.1} µs | {:>8.1} req/s",
            rep.p50_us, rep.p95_us, rep.p99_us, rep.qps
        );
        cases.push(rep.to_json(name, clients, ppr));
    }

    // Server-side batching view for the record.
    if let Ok((_, metrics)) = client::get_json(&addr, "/metrics") {
        if let Some(b) = metrics.get("batching") {
            cases.push(Json::obj(vec![
                ("name", Json::str("server_batching")),
                (
                    "batches",
                    Json::num(b.get("batches").and_then(Json::as_f64).unwrap_or(0.0)),
                ),
                (
                    "points",
                    Json::num(b.get("points").and_then(Json::as_f64).unwrap_or(0.0)),
                ),
                (
                    "max_points_in_batch",
                    Json::num(b.get("max_points_in_batch").and_then(Json::as_f64).unwrap_or(0.0)),
                ),
            ]));
        }
    }
    handle.shutdown();

    isospark::bench::write_kernel_section("BENCH_serve.json", "serve_latency", cases);

    // Soak ladder on a fresh autoscaling server: double the offered QPS
    // until the replica stops keeping up, and record the knee of the
    // latency/throughput curve (same shape `isospark bench-serve --soak`
    // writes, so CI dashboards read one format).
    let model = StreamingModel::fit(&ds.points, &cfg, m, &ClusterConfig::local(), &Backend::Native)
        .expect("refit")
        .into_model();
    let handle = serve::start(
        model,
        None,
        None,
        &ServeConfig { threads_min: 1, threads_max: 4, ..ServeConfig::default() },
    )
    .expect("start soak server");
    let addr = handle.addr();
    let outcome =
        client::soak(&addr, "/v1/embed", 25.0, 1600.0, 1.5, 4, &pool).expect("soak ladder");
    let mut soak_cases: Vec<Json> = outcome.steps.iter().map(|s| s.to_json()).collect();
    for s in &outcome.steps {
        println!(
            "soak @ {:>7.0} qps target: {:>7.1} achieved | p95 {:>9.1} µs | shed {:>4.1}%",
            s.target_qps,
            s.achieved_qps,
            s.p95_us,
            s.shed_fraction() * 100.0
        );
    }
    println!(
        "knee: {:.1} qps @ p95 {:.1} µs ({})",
        outcome.knee_qps,
        outcome.knee_p95_us,
        if outcome.saturated { "saturated" } else { "qps ceiling reached" }
    );
    soak_cases.push(Json::obj(vec![
        ("name", Json::str("knee")),
        ("knee_qps", Json::num(outcome.knee_qps)),
        ("knee_p95_us", Json::num(outcome.knee_p95_us)),
        ("saturated", Json::Bool(outcome.saturated)),
    ]));
    handle.shutdown();

    isospark::bench::write_kernel_section("BENCH_serve.json", "serve_soak", soak_cases);
    println!("wrote BENCH_serve.json");
}
