//! Ablation: native Rust kernels vs the AOT Pallas/JAX artifacts through
//! PJRT — the reproduction's analogue of the paper's "offload to BLAS"
//! argument. Per-op block throughput plus the end-to-end pipeline on each
//! backend. Skips PJRT cases when `make artifacts` has not run.
//!
//! Run: `cargo bench --bench ablation_backend`

use isospark::backend::Backend;
use isospark::bench::Bencher;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::isomap;
use isospark::data::swiss_roll;
use isospark::kernels;
use isospark::linalg::Matrix;
use isospark::runtime::PjrtEngine;
use isospark::util::Rng;
use std::path::Path;

fn random(r: usize, c: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut m = Matrix::zeros(r, c);
    for i in 0..r {
        for j in 0..c {
            m[(i, j)] = rng.range(0.0, 5.0);
        }
    }
    m
}

fn main() {
    let mut bench = Bencher::with(4.0, 10, 1);
    let rt = PjrtEngine::load(Path::new("artifacts")).ok();
    if rt.is_none() {
        println!("(PJRT artifacts missing — native-only run; `make artifacts` to compare)");
    }

    for b in [64usize, 128] {
        let a = random(b, b, 1);
        let c = random(b, b, 2);
        let mut dst = Matrix::full(b, b, f64::INFINITY);
        bench.case(&format!("minplus:native:b{b}"), || {
            kernels::minplus::minplus_into(&a, &c, &mut dst);
        });
        if let Some(rt) = &rt {
            bench.case(&format!("minplus:pjrt:b{b}"), || {
                rt.minplus(&a, &c).unwrap();
            });
        }

        let xi = random(b, 784, 3);
        let xj = random(b, 784, 4);
        bench.case(&format!("dist:native:b{b}:D784"), || {
            kernels::sqdist::dist_block(&xi, &xj);
        });
        if let Some(rt) = &rt {
            bench.case(&format!("dist:pjrt:b{b}:D784"), || {
                rt.dist_block(&xi, &xj).unwrap();
            });
        }

        let g = random(b, b, 5);
        bench.case(&format!("fw:native:b{b}"), || {
            kernels::floyd_warshall::floyd_warshall(&g);
        });
        if let Some(rt) = &rt {
            bench.case(&format!("fw:pjrt:b{b}"), || {
                rt.floyd_warshall(&g).unwrap();
            });
        }
    }

    // End-to-end on each backend.
    println!("\n== end-to-end pipeline by backend (n=512, b=128) ==");
    let ds = swiss_roll::euler_isometric(512, 11);
    let cfg = IsomapConfig { k: 10, d: 2, block: 128, ..Default::default() };
    // warmup=1 so the PJRT case's one-time executable compiles are not
    // measured.
    let mut e2e = Bencher::with(20.0, 3, 1);
    e2e.case("pipeline:native", || {
        isomap::run_with(&ds.points, &cfg, &ClusterConfig::local(), &Backend::Native).unwrap();
    });
    if rt.is_some() {
        let be = Backend::pjrt_from_dir(Path::new("artifacts")).unwrap();
        e2e.case("pipeline:pjrt", || {
            isomap::run_with(&ds.points, &cfg, &ClusterConfig::local(), &be).unwrap();
        });
    }

    std::fs::create_dir_all("out").ok();
    std::fs::write("out/ablation_backend.json", bench.json()).ok();
}
