//! Bench: regenerate paper Tables I–III (execution time / relative speedup
//! / relative efficiency across 2–24 nodes for the five benchmark
//! datasets) on the simulated testbed, cost model calibrated from this
//! machine's kernels. Writes `out/table1.json`.
//!
//! Run: `cargo bench --bench table1_scaling`

use isospark::bench::Bencher;
use isospark::config::ClusterConfig;
use isospark::sim::{self, CostModel, Workload};

fn main() {
    println!("== Table I–III: scalability on the simulated paper testbed ==");
    let model = CostModel::calibrate(256);
    let mut bench = Bencher::new();
    let nodes = [2usize, 4, 8, 12, 16, 20, 24];
    for w in Workload::paper_suite(1500) {
        let mut base: Option<(f64, usize)> = None;
        for &p in &nodes {
            let proj = sim::project(&w, &ClusterConfig::paper_testbed(p), &model);
            match proj.total_secs {
                None => println!("table1:{}:p{p:<2} {:>44}", w.name, "- (out of memory)"),
                Some(t) => {
                    if base.is_none() {
                        base = Some((t, p));
                    }
                    let (tb, pb) = base.unwrap();
                    bench.report_value(&format!("table1:{}:p{p}:minutes", w.name), t / 60.0, "min");
                    bench.report_value(&format!("table2:{}:p{p}:speedup", w.name), tb / t, "x");
                    bench.report_value(
                        &format!("table3:{}:p{p}:efficiency", w.name),
                        (tb / t) * pb as f64 / p as f64,
                        "",
                    );
                }
            }
        }
    }
    std::fs::create_dir_all("out").ok();
    std::fs::write("out/table1.json", bench.json()).ok();
    println!("JSON written to out/table1.json");
}
