//! Bench: the kNN stage (distance blocks + heap top-k + graph fill) on the
//! real engine, across block sizes and ambient dimensionality — the
//! paper's §III-A workload. Reports measured single-core compute and the
//! shuffle volume the custom partitioner produces.
//!
//! Run: `cargo bench --bench stage_knn`

use isospark::backend::Backend;
use isospark::bench::Bencher;
use isospark::config::{ClusterConfig, IsomapConfig, KnnMode};
use isospark::coordinator::knn;
use isospark::data::{emnist_synth, swiss_roll};
use isospark::engine::SparkContext;
use isospark::eval;
use isospark::kernels::sqdist;
use isospark::linalg::Matrix;
use isospark::util::json::Json;
use isospark::util::Rng;

/// Pre-tiling `dist_block` (per-(i,j) scalar dot with 4 accumulators) —
/// kept bench-local as the baseline the packed Gram kernel is measured
/// against.
fn dist_block_ref(xi: &Matrix, xj: &Matrix) -> Matrix {
    let bi = xi.nrows();
    let bj = xj.nrows();
    let ni = sqdist::row_sqnorms(xi);
    let nj = sqdist::row_sqnorms(xj);
    let mut out = Matrix::zeros(bi, bj);
    for i in 0..bi {
        let xr = xi.row(i);
        let orow = out.row_mut(i);
        for j in 0..bj {
            let yr = xj.row(j);
            let mut acc = [0.0f64; 4];
            let chunks = xr.len() / 4;
            for c in 0..chunks {
                let base = 4 * c;
                acc[0] += xr[base] * yr[base];
                acc[1] += xr[base + 1] * yr[base + 1];
                acc[2] += xr[base + 2] * yr[base + 2];
                acc[3] += xr[base + 3] * yr[base + 3];
            }
            let mut dot = (acc[0] + acc[1]) + (acc[2] + acc[3]);
            for t in 4 * chunks..xr.len() {
                dot += xr[t] * yr[t];
            }
            let d2 = ni[i] + nj[j] - 2.0 * dot;
            orow[j] = if d2 > 0.0 { d2.sqrt() } else { 0.0 };
        }
    }
    out
}

fn random_points(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut x = Matrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            x[(i, j)] = rng.gaussian();
        }
    }
    x
}

fn main() {
    let mut bench = Bencher::with(6.0, 5, 1);

    // Kernel throughput: packed Gram distance kernel vs the bench-local
    // pre-tiling scalar-dot reference, merged into BENCH_kernels.json
    // alongside stage_apsp's min-plus/gemm section.
    println!("== kernel throughput: tiled vs pre-tiling reference ==");
    let mut kernel_cases: Vec<Json> = Vec::new();
    for (b, dim) in [(256usize, 16usize), (256, 784), (128, 784)] {
        let xi = random_points(b, dim, 1);
        let xj = random_points(b, dim, 2);
        let ops = 2.0 * (b as f64) * (b as f64) * (dim as f64);
        let tiled = bench.case(&format!("dist:tiled:b{b}:D{dim}"), || {
            std::hint::black_box(sqdist::dist_block(&xi, &xj));
        });
        let base = bench.case(&format!("dist:ref:b{b}:D{dim}"), || {
            std::hint::black_box(dist_block_ref(&xi, &xj));
        });
        bench.report_value(&format!("dist:tiled_speedup:b{b}:D{dim}"), base / tiled, "x");
        kernel_cases.push(Json::obj(vec![
            ("kernel", Json::str("dist_block")),
            ("b", Json::num(b as f64)),
            ("dim", Json::num(dim as f64)),
            ("tiled_secs", Json::num(tiled)),
            ("ref_secs", Json::num(base)),
            ("tiled_gops", Json::num(ops / tiled / 1e9)),
            ("ref_gops", Json::num(ops / base / 1e9)),
            ("speedup", Json::num(base / tiled)),
        ]));
    }
    {
        // Symmetric diagonal block: upper triangle + mirror vs full block.
        let (b, dim) = (256usize, 64usize);
        let x = random_points(b, dim, 3);
        let full = bench.case(&format!("dist:full_diag:b{b}:D{dim}"), || {
            std::hint::black_box(sqdist::dist_block(&x, &x));
        });
        let sym = bench.case(&format!("dist:sym_diag:b{b}:D{dim}"), || {
            std::hint::black_box(sqdist::dist_block_sym(&x));
        });
        bench.report_value(&format!("dist:sym_speedup:b{b}:D{dim}"), full / sym, "x");
        kernel_cases.push(Json::obj(vec![
            ("kernel", Json::str("dist_block_sym")),
            ("b", Json::num(b as f64)),
            ("dim", Json::num(dim as f64)),
            ("tiled_secs", Json::num(sym)),
            ("ref_secs", Json::num(full)),
            ("speedup", Json::num(full / sym)),
        ]));
    }
    isospark::bench::write_kernel_section("BENCH_kernels.json", "stage_knn", kernel_cases);
    println!("(kernel throughput written to BENCH_kernels.json)\n");

    let n = 1024;
    let swiss = swiss_roll::euler_isometric(n, 5);
    for b in [64usize, 128, 256] {
        let cfg = IsomapConfig { k: 10, block: b, ..Default::default() };
        bench.case(&format!("knn:swiss:n{n}:b{b}:D3"), || {
            let ctx = SparkContext::new(ClusterConfig::local());
            let g = knn::build(&ctx, &swiss.points, &cfg, &Backend::Native).unwrap();
            assert_eq!(g.lists.len(), n);
        });
    }

    let emnist = emnist_synth::generate(512, 5);
    for b in [64usize, 128] {
        let cfg = IsomapConfig { k: 10, block: b, ..Default::default() };
        bench.case(&format!("knn:emnist:n512:b{b}:D784"), || {
            let ctx = SparkContext::new(ClusterConfig::local());
            let g = knn::build(&ctx, &emnist.points, &cfg, &Backend::Native).unwrap();
            assert_eq!(g.lists.len(), 512);
        });
    }

    // Exact vs rp-forest front end: build+query time, speedup, recall and
    // the candidate-pair fraction, written to BENCH_knn.json. Block 512
    // keeps engine overhead (pair shuffle, block count) proportionate at
    // the larger sizes; both paths see the identical configuration apart
    // from the `knn` fork. One measured iteration per case — the exact
    // path at n = 32768 is the very O(n²) wall this section demonstrates.
    println!("\n== exact vs rp-forest front end ==");
    let mut fe = Bencher::with(20.0, 2, 0);
    let mut frontend_cases: Vec<Json> = Vec::new();
    for n in [2048usize, 8192, 32768] {
        let ds = swiss_roll::euler_isometric(n, 11);
        let cluster = ClusterConfig {
            parallelism: 0, // all physical cores
            cores_per_node: 8,
            ..ClusterConfig::local()
        };
        let exact_cfg = IsomapConfig { k: 10, block: 512, ..Default::default() };
        let rp_cfg = IsomapConfig { knn: KnnMode::RpForest, ..exact_cfg.clone() };

        let mut exact_lists = None;
        let exact_secs = fe.case(&format!("knn:frontend:exact:n{n}"), || {
            let ctx = SparkContext::new(cluster.clone());
            let kl = knn::build_lists(&ctx, &ds.points, &exact_cfg, &Backend::Native).unwrap();
            exact_lists = Some(kl.lists);
        });
        let mut rp_lists = None;
        let mut rp_stats = None;
        let rp_secs = fe.case(&format!("knn:frontend:rp-forest:n{n}"), || {
            let ctx = SparkContext::new(cluster.clone());
            let kl = knn::build_lists(&ctx, &ds.points, &rp_cfg, &Backend::Native).unwrap();
            let knn::KnnPath::RpForest(stats) = kl.path else { unreachable!() };
            rp_stats = Some(stats);
            rp_lists = Some(kl.lists);
        });

        let stats = rp_stats.unwrap();
        let recall = eval::recall_at_k(&rp_lists.unwrap(), &exact_lists.unwrap(), 10);
        let exact_pairs = (n as u64) * (n as u64 - 1) / 2;
        // Acceptance criterion: sub-quadratic candidate generation.
        assert!(
            stats.candidate_pairs < (n as u64) * (n as u64) / 5,
            "n={n}: candidate pairs {} ≥ 20% of n²",
            stats.candidate_pairs
        );
        fe.report_value(&format!("knn:frontend:speedup:n{n}"), exact_secs / rp_secs, "x");
        fe.report_value(&format!("knn:frontend:recall@10:n{n}"), recall, "");
        fe.report_value(
            &format!("knn:frontend:pair_frac:n{n}"),
            100.0 * stats.pair_fraction(),
            "% of n²",
        );
        frontend_cases.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("k", Json::num(10.0)),
            ("block", Json::num(512.0)),
            ("trees", Json::num(stats.trees as f64)),
            ("leaf_size", Json::num(stats.leaf_size as f64)),
            ("exact_secs", Json::num(exact_secs)),
            ("rp_secs", Json::num(rp_secs)),
            ("speedup", Json::num(exact_secs / rp_secs)),
            ("recall_at_10", Json::num(recall)),
            ("exact_pairs", Json::num(exact_pairs as f64)),
            ("candidate_pairs", Json::num(stats.candidate_pairs as f64)),
            ("pair_fraction_of_n2", Json::num(stats.pair_fraction())),
            ("mean_distinct_candidates", Json::num(stats.mean_distinct_candidates)),
            ("full_fraction", Json::num(stats.full_fraction)),
        ]));
    }
    isospark::bench::write_kernel_section("BENCH_knn.json", "stage_knn_frontend", frontend_cases);
    println!("(front-end comparison written to BENCH_knn.json)\n");

    // Shuffle accounting on a multi-node simulated cluster.
    let cfg = IsomapConfig { k: 10, block: 128, ..Default::default() };
    let ctx = SparkContext::new(ClusterConfig::paper_testbed(4));
    knn::build(&ctx, &swiss.points, &cfg, &Backend::Native).unwrap();
    bench.report_value(
        "knn:swiss:n1024:b128:shuffle",
        ctx.total_shuffle_bytes() as f64 / (1 << 20) as f64,
        "MiB",
    );

    std::fs::create_dir_all("out").ok();
    std::fs::write("out/stage_knn.json", bench.json()).ok();
}
