//! Bench: the kNN stage (distance blocks + heap top-k + graph fill) on the
//! real engine, across block sizes and ambient dimensionality — the
//! paper's §III-A workload. Reports measured single-core compute and the
//! shuffle volume the custom partitioner produces.
//!
//! Run: `cargo bench --bench stage_knn`

use isospark::backend::Backend;
use isospark::bench::Bencher;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::knn;
use isospark::data::{emnist_synth, swiss_roll};
use isospark::engine::SparkContext;

fn main() {
    let mut bench = Bencher::with(6.0, 5, 1);

    let n = 1024;
    let swiss = swiss_roll::euler_isometric(n, 5);
    for b in [64usize, 128, 256] {
        let cfg = IsomapConfig { k: 10, block: b, ..Default::default() };
        bench.case(&format!("knn:swiss:n{n}:b{b}:D3"), || {
            let ctx = SparkContext::new(ClusterConfig::local());
            let g = knn::build(&ctx, &swiss.points, &cfg, &Backend::Native).unwrap();
            assert_eq!(g.lists.len(), n);
        });
    }

    let emnist = emnist_synth::generate(512, 5);
    for b in [64usize, 128] {
        let cfg = IsomapConfig { k: 10, block: b, ..Default::default() };
        bench.case(&format!("knn:emnist:n512:b{b}:D784"), || {
            let ctx = SparkContext::new(ClusterConfig::local());
            let g = knn::build(&ctx, &emnist.points, &cfg, &Backend::Native).unwrap();
            assert_eq!(g.lists.len(), 512);
        });
    }

    // Shuffle accounting on a multi-node simulated cluster.
    let cfg = IsomapConfig { k: 10, block: 128, ..Default::default() };
    let ctx = SparkContext::new(ClusterConfig::paper_testbed(4));
    knn::build(&ctx, &swiss.points, &cfg, &Backend::Native).unwrap();
    bench.report_value(
        "knn:swiss:n1024:b128:shuffle",
        ctx.total_shuffle_bytes() as f64 / (1 << 20) as f64,
        "MiB",
    );

    std::fs::create_dir_all("out").ok();
    std::fs::write("out/stage_knn.json", bench.json()).ok();
}
