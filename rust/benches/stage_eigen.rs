//! Bench: simultaneous power iteration (paper §III-D) — per-iteration cost
//! of the blocked A·Q product + driver QR, across block sizes and d.
//!
//! Run: `cargo bench --bench stage_eigen`

use isospark::backend::Backend;
use isospark::bench::Bencher;
use isospark::config::ClusterConfig;
use isospark::coordinator::{blocks_from_dense, eigen, num_blocks};
use isospark::engine::partitioner::UpperTriangularPartitioner;
use isospark::engine::SparkContext;
use isospark::linalg::{qr::qr_thin, Matrix};
use isospark::util::Rng;
use std::sync::Arc;

fn random_symmetric(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let x = rng.gaussian();
            m[(i, j)] = x;
            m[(j, i)] = x;
        }
    }
    m
}

fn main() {
    let mut bench = Bencher::with(5.0, 5, 1);

    // Driver-side QR on tall-skinny V (what the paper offloads to BLAS).
    for (n, d) in [(1024usize, 2usize), (1024, 8), (4096, 2)] {
        let mut rng = Rng::seed(3);
        let mut v = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                v[(i, j)] = rng.gaussian();
            }
        }
        bench.case(&format!("eigen:qr:n{n}:d{d}"), || {
            let (q, _) = qr_thin(&v);
            assert_eq!(q.ncols(), d);
        });
    }

    // Full power iteration over the engine.
    let n = 1024;
    for (b, d) in [(128usize, 2usize), (128, 3), (256, 2)] {
        let m = random_symmetric(n, 7);
        let q = num_blocks(n, b);
        bench.case(&format!("eigen:power:n{n}:b{b}:d{d}"), || {
            let ctx = SparkContext::new(ClusterConfig::local());
            let part = Arc::new(UpperTriangularPartitioner::new(q, q))
                as Arc<dyn isospark::engine::Partitioner>;
            let rdd = ctx.parallelize("a", blocks_from_dense(&m, b), part);
            let out = eigen::simultaneous_power_iteration(
                &rdd,
                n,
                b,
                d,
                1e-6,
                40,
                &Backend::Native,
            )
            .unwrap();
            assert!(out.iterations > 0);
        });
    }

    std::fs::create_dir_all("out").ok();
    std::fs::write("out/stage_eigen.json", bench.json()).ok();
}
