//! Bench: simultaneous power iteration (paper §III-D) — per-iteration cost
//! of the blocked A·Q product + driver QR, across block sizes and d.
//!
//! Run: `cargo bench --bench stage_eigen`

use isospark::backend::Backend;
use isospark::bench::{write_kernel_section, Bencher};
use isospark::config::{ClusterConfig, FeatureMode, GeodesicsMode, IsomapConfig, KnnMode};
use isospark::coordinator::{blocks_from_dense, eigen, isomap, num_blocks};
use isospark::engine::partitioner::UpperTriangularPartitioner;
use isospark::engine::SparkContext;
use isospark::linalg::{qr::qr_thin, Matrix};
use isospark::util::json::Json;
use isospark::util::{Rng, Stopwatch};
use std::sync::Arc;

fn random_symmetric(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let x = rng.gaussian();
            m[(i, j)] = x;
            m[(j, i)] = x;
        }
    }
    m
}

fn main() {
    let mut bench = Bencher::with(5.0, 5, 1);

    // Driver-side QR on tall-skinny V (what the paper offloads to BLAS).
    for (n, d) in [(1024usize, 2usize), (1024, 8), (4096, 2)] {
        let mut rng = Rng::seed(3);
        let mut v = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                v[(i, j)] = rng.gaussian();
            }
        }
        bench.case(&format!("eigen:qr:n{n}:d{d}"), || {
            let (q, _) = qr_thin(&v);
            assert_eq!(q.ncols(), d);
        });
    }

    // Full power iteration over the engine.
    let n = 1024;
    for (b, d) in [(128usize, 2usize), (128, 3), (256, 2)] {
        let m = random_symmetric(n, 7);
        let q = num_blocks(n, b);
        bench.case(&format!("eigen:power:n{n}:b{b}:d{d}"), || {
            let ctx = SparkContext::new(ClusterConfig::local());
            let part = Arc::new(UpperTriangularPartitioner::new(q, q))
                as Arc<dyn isospark::engine::Partitioner>;
            let rdd = ctx.parallelize("a", blocks_from_dense(&m, b), part);
            let out = eigen::simultaneous_power_iteration(
                &rdd,
                n,
                b,
                d,
                1e-6,
                40,
                &Backend::Native,
            )
            .unwrap();
            assert!(out.iterations > 0);
        });
    }

    // Materialized vs implicit feature source, end to end: wall time,
    // panel recomputes, and the measured peak resident bytes that justify
    // the O(n·k + b·n) claim. rp-forest kNN for both modes so the exact
    // front end's O(n²) distance blocks don't mask the feature-matrix
    // difference; a fixed handful of power iterations (the peak is set by
    // residency, not convergence), one timed run per case (a full n = 8192
    // fit is far past the micro-bench budget).
    let mut memory_cases = Vec::new();
    for n in [2048usize, 8192] {
        let ds = isospark::data::swiss_roll::euler_isometric(n, 13);
        for feature in [FeatureMode::Materialized, FeatureMode::Implicit] {
            let cfg = IsomapConfig {
                k: 10,
                d: 2,
                block: 256,
                max_iter: 4,
                tol: 1e-30,
                knn: KnnMode::RpForest,
                geodesics: GeodesicsMode::SparseDijkstra,
                feature,
                ..Default::default()
            };
            let cluster = ClusterConfig { parallelism: 0, ..ClusterConfig::local() };
            let sw = Stopwatch::start();
            let out = isomap::run(&ds.points, &cfg, &cluster).unwrap();
            let wall = sw.secs();
            println!(
                "eigen:memory:n{n}:{:<12} {wall:>8.3}s  peak {:>12} B  {} panel recomputes",
                feature.name(),
                out.peak_resident_bytes,
                out.panel_recomputes
            );
            memory_cases.push(Json::obj(vec![
                ("n", Json::num(n as f64)),
                ("d", Json::num(2.0)),
                ("block", Json::num(256.0)),
                ("mode", Json::str(feature.name())),
                ("wall_secs", Json::num(wall)),
                ("iterations", Json::num(out.eigen_iterations as f64)),
                ("panel_recomputes", Json::num(out.panel_recomputes as f64)),
                ("peak_resident_bytes", Json::num(out.peak_resident_bytes as f64)),
            ]));
        }
    }
    write_kernel_section("BENCH_memory.json", "stage_eigen:memory", memory_cases);

    std::fs::create_dir_all("out").ok();
    std::fs::write("out/stage_eigen.json", bench.json()).ok();
}
