//! Bench: regenerate paper Fig. 6 — total execution time of Swiss75 on 24
//! nodes as a function of the logical block size b (the U-shaped curve
//! with the sweet spot near b=1500–2500). Also runs a *real* engine sweep
//! at laptop scale (n=1024) to show the same U-shape in actual seconds.
//!
//! Run: `cargo bench --bench fig6_blocksize`

use isospark::bench::Bencher;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::isomap;
use isospark::data::swiss_roll;
use isospark::sim::{self, CostModel, Workload};

fn main() {
    let mut bench = Bencher::new();

    println!("== Fig. 6 (paper scale, simulated): Swiss75 @ 24 nodes ==");
    let model = CostModel::calibrate(256);
    for b in [500usize, 750, 1000, 1500, 2000, 2500, 3000, 4000] {
        let w = Workload::new("Swiss75", 75_000, 3, b);
        let proj = sim::project(&w, &ClusterConfig::paper_testbed(24), &model);
        bench.report_value(
            &format!("fig6:sim:b{b}:minutes"),
            proj.total_secs.unwrap() / 60.0,
            "min",
        );
    }

    println!("\n== Fig. 6 (laptop scale, real engine): n=1024 swiss roll ==");
    let ds = swiss_roll::euler_isometric(1024, 6);
    let mut real = Bencher::with(8.0, 3, 0);
    for b in [32usize, 64, 128, 256, 512] {
        let cfg = IsomapConfig { k: 10, d: 2, block: b, ..Default::default() };
        real.case(&format!("fig6:real:b{b}"), || {
            let out = isomap::run(&ds.points, &cfg, &ClusterConfig::local()).unwrap();
            assert_eq!(out.graph_components, 1);
        });
    }

    std::fs::create_dir_all("out").ok();
    std::fs::write("out/fig6.json", bench.json()).ok();
    println!("JSON written to out/fig6.json");
}
