//! Bench: the distributed geodesic panel stage — single process vs real
//! worker processes over loopback TCP.
//!
//! This is the repo's first *measured* (not virtual-clock) distribution
//! record: the same sparse-Dijkstra pipeline at n = 1024, executed with 0
//! (single-process), 2, and 4 in-process workers, with the TCP byte
//! traffic from the driver's own accounting. Output bits are asserted
//! identical across all configurations before anything is recorded.
//!
//! Run: `cargo bench --bench stage_dist` (writes BENCH_dist.json)

use isospark::backend::Backend;
use isospark::bench::Bencher;
use isospark::config::{ClusterConfig, GeodesicsMode, IsomapConfig};
use isospark::coordinator::isomap;
use isospark::data::swiss_roll;
use isospark::dist::worker::{self, WorkerHandle, WorkerOptions};
use isospark::util::json::Json;

fn main() {
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let (n, k, b) = (1024usize, 10usize, 128usize);
    let ds = swiss_roll::euler_isometric(n, 17);
    let cfg = IsomapConfig {
        k,
        d: 2,
        block: b,
        geodesics: GeodesicsMode::SparseDijkstra,
        ..Default::default()
    };
    let cluster_for = |addrs: Vec<String>| ClusterConfig {
        dist_workers: addrs,
        parallelism: cores,
        ..ClusterConfig::local()
    };
    let run = |cluster: &ClusterConfig| {
        isomap::run_with(&ds.points, &cfg, cluster, &Backend::Native).expect("pipeline run")
    };

    println!("== distributed geodesics: single process vs loopback worker fleets ==");
    let baseline = run(&cluster_for(Vec::new()));

    let mut bench = Bencher::with(15.0, 2, 1);
    let mut cases: Vec<Json> = Vec::new();
    for nworkers in [0usize, 2, 4] {
        // Workers outlive the timed iterations (the deployment model: a
        // standing fleet serving many driver runs); each run pays its own
        // connect + broadcast + stage, which is the real driver cost.
        let handles: Vec<WorkerHandle> = (0..nworkers)
            .map(|_| worker::spawn("127.0.0.1:0", WorkerOptions::default()).expect("spawn"))
            .collect();
        let addrs: Vec<String> = handles.iter().map(WorkerHandle::addr).collect();
        let cluster = cluster_for(addrs);

        // Bit-identity gate: a perf record of a wrong answer is worthless.
        let probe = run(&cluster);
        for (x, y) in probe.embedding.as_slice().iter().zip(baseline.embedding.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{nworkers}-worker embedding diverged");
        }

        let label = if nworkers == 0 {
            "dist:single-process".to_string()
        } else {
            format!("dist:{nworkers}-workers")
        };
        let secs = bench.case(&format!("{label}:n{n}:b{b}"), || {
            run(&cluster);
        });

        let mut obj = vec![
            ("workers", Json::num(nworkers as f64)),
            ("n", Json::num(n as f64)),
            ("b", Json::num(b as f64)),
            ("k", Json::num(k as f64)),
            ("threads", Json::num(cores as f64)),
            ("pipeline_secs", Json::num(secs)),
        ];
        if let Some(d) = probe.dist {
            bench.report_value(
                &format!("{label}:tcp_mb"),
                (d.bytes_sent + d.bytes_received) as f64 / 1e6,
                "MB",
            );
            obj.push(("stage_wall_secs", Json::num(d.wall_secs)));
            obj.push(("stage_virtual_secs", Json::num(d.virtual_secs)));
            obj.push(("bytes_sent", Json::num(d.bytes_sent as f64)));
            obj.push(("bytes_received", Json::num(d.bytes_received as f64)));
            obj.push(("retries", Json::num(d.retries as f64)));
        }
        cases.push(Json::obj(obj));
        drop(handles);
    }

    isospark::bench::write_kernel_section("BENCH_dist.json", "stage_dist", cases);
    println!("(measured distribution record written to BENCH_dist.json)");
}
