//! Ablation: the paper's custom upper-triangular partitioner vs MLlib-like
//! Grid vs Spark-default Hash (§III-A, Fig. 2). Runs the APSP stage with
//! each partitioner on a simulated 4-node cluster and reports shuffle
//! volume and virtual time — the locality benefit the paper claims.
//!
//! Run: `cargo bench --bench ablation_partitioner`

use isospark::backend::Backend;
use isospark::bench::Bencher;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::{apsp, blocks_from_dense, num_blocks};
use isospark::engine::partitioner::{GridPartitioner, HashPartitioner, UpperTriangularPartitioner};
use isospark::engine::{Partitioner, SparkContext};
use isospark::linalg::Matrix;
use isospark::util::Rng;
use std::sync::Arc;

fn random_graph(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let mut g = Matrix::full(n, n, f64::INFINITY);
    for i in 0..n {
        g[(i, i)] = 0.0;
        let j = (i + 1) % n;
        let w = rng.range(0.1, 1.0);
        g[(i, j)] = w;
        g[(j, i)] = w;
    }
    g
}

fn main() {
    let mut bench = Bencher::with(4.0, 3, 0);
    let n = 1536;
    let b = 64;
    let q = num_blocks(n, b);
    let g = random_graph(n, 1);
    let cfg = IsomapConfig { block: b, ..Default::default() };
    let cluster = ClusterConfig::paper_testbed(4);
    // B = Q/p' ≈ 4 consecutive blocks per partition — the packing regime
    // of the paper's Fig. 2 (p' < Q). NOTE on interpretation: this harness
    // feeds all three partitioners the upper-triangular block set; real
    // MLlib GridPartitioner only partitions *full* matrices (both
    // triangles = 2× blocks, 2× memory/compute), which is the paper's
    // core objection to it. The headline comparison is UT vs the Spark
    // default (hash).
    let parts = q * (q + 1) / 2 / 4;

    let cases: Vec<(&str, Arc<dyn Partitioner>)> = vec![
        ("upper-triangular", Arc::new(UpperTriangularPartitioner::new(q, parts))),
        ("grid", Arc::new(GridPartitioner::new(q, parts))),
        ("hash", Arc::new(HashPartitioner::new(parts))),
    ];

    println!("== APSP shuffle volume & virtual time by partitioner (n={n}, b={b}, 4 nodes) ==");
    for (name, part) in cases {
        let ctx = SparkContext::new(cluster.clone());
        let rdd = ctx.parallelize("g", blocks_from_dense(&g, b), Arc::clone(&part));
        let sw = isospark::util::Stopwatch::start();
        let out = apsp::solve(rdd, q, &cfg, &Backend::Native).unwrap();
        let wall = sw.secs();
        assert_eq!(out.len(), q * (q + 1) / 2);
        bench.report_value(
            &format!("partitioner:{name}:shuffle"),
            ctx.total_shuffle_bytes() as f64 / (1 << 20) as f64,
            "MiB",
        );
        bench.report_value(&format!("partitioner:{name}:virtual"), ctx.virtual_now(), "virt-s");
        bench.report_value(&format!("partitioner:{name}:wall"), wall, "s");
    }

    std::fs::create_dir_all("out").ok();
    std::fs::write("out/ablation_partitioner.json", bench.json()).ok();
}
