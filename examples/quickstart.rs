//! Quickstart: 30 lines from dataset to embedding.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::isomap;
use isospark::data::swiss_roll;
use isospark::eval::procrustes;

fn main() -> anyhow::Result<()> {
    // 1. A dataset: 600 points on the isometric swiss roll (D = 3).
    let ds = swiss_roll::euler_isometric(600, 42);

    // 2. Isomap hyper-parameters (paper defaults: k=10, tol=1e-9, l=100).
    let cfg = IsomapConfig { k: 10, d: 2, block: 64, ..Default::default() };

    // 3. A cluster: local() = single executor, free network — pure compute.
    let cluster = ClusterConfig::local();

    // 4. Run the four-stage pipeline (kNN → APSP → centering → eigen).
    let out = isomap::run(&ds.points, &cfg, &cluster)?;

    println!("embedding: {} × {}", out.embedding.nrows(), out.embedding.ncols());
    println!("eigenvalues: {:?}", out.eigenvalues);
    println!("kNN graph components: {}", out.graph_components);
    let err = procrustes(ds.ground_truth.as_ref().unwrap(), &out.embedding);
    println!("procrustes error vs latent ground truth: {err:.3e}");
    assert!(err < 1e-2, "embedding failed to recover the manifold");
    println!("OK");
    Ok(())
}
