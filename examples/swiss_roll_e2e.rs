//! END-TO-END DRIVER (paper Fig. 4 at laptop scale).
//!
//! Exercises the full three-layer system on a real workload:
//!   * L1/L2: the AOT Pallas/JAX artifacts execute every full-block
//!     distance, min-plus, Floyd–Warshall, centering and gemm op through
//!     the PJRT runtime (falls back to the native backend with a warning
//!     if `make artifacts` has not been run);
//!   * L3: the blocked dataflow engine on a simulated 4-node paper
//!     testbed, with shuffle accounting, lineage checkpointing and the
//!     virtual clock.
//!
//! n = 2048 swiss-roll points (divisible by b = 128 so the hot path stays
//! on PJRT), k = 10, d = 2 — then reports Procrustes error vs the latent
//! rectangle, residual variance, per-stage metrics, and writes
//! `out/swiss_e2e_embedding.csv`. Recorded in EXPERIMENTS.md §F4.
//!
//! ```bash
//! make artifacts && cargo run --release --example swiss_roll_e2e
//! ```

use isospark::backend::Backend;

use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::isomap;
use isospark::data::{io, swiss_roll};
use isospark::eval;
use isospark::util::fmt::{human_bytes, human_duration};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let n = 2048;
    let ds = swiss_roll::euler_isometric(n, 4);
    let cfg = IsomapConfig { k: 10, d: 2, block: 128, ..Default::default() };
    let cluster = ClusterConfig::paper_testbed(4);

    let backend = match Backend::pjrt_from_dir(Path::new("artifacts")) {
        Ok(b) => {
            println!("backend: pjrt (AOT Pallas/JAX artifacts)");
            b
        }
        Err(e) => {
            println!("backend: native (PJRT unavailable: {e:#})");
            Backend::Native
        }
    };

    println!(
        "swiss roll: n={n} D=3 | k={} d={} b={} | 4-node simulated testbed",
        cfg.k, cfg.d, cfg.block
    );
    let sw = isospark::util::Stopwatch::start();
    let out = isomap::run_with(&ds.points, &cfg, &cluster, &backend)?;
    let wall = sw.secs();

    let truth = ds.ground_truth.as_ref().unwrap();
    let perr = eval::procrustes(truth, &out.embedding);

    // Residual variance against the *true* geodesics: the roll is
    // isometric to the latent rectangle, so latent Euclidean distances are
    // exact manifold distances (no graph approximation error in the
    // reference). Computed on a subsample.
    let sub: Vec<usize> = (0..n).step_by(8).collect();
    let m = sub.len();
    let mut true_geo = isospark::linalg::Matrix::zeros(m, m);
    let mut ys = isospark::linalg::Matrix::zeros(m, 2);
    for (r, &i) in sub.iter().enumerate() {
        ys.row_mut(r).copy_from_slice(out.embedding.row(i));
        for (c, &j) in sub.iter().enumerate() {
            let dt = truth[(i, 0)] - truth[(j, 0)];
            let dh = truth[(i, 1)] - truth[(j, 1)];
            true_geo[(r, c)] = (dt * dt + dh * dh).sqrt();
        }
    }
    let rv = eval::residual_variance(&true_geo, &ys, 20_000);

    println!("\n=== results (EXPERIMENTS.md §F4) ===");
    println!("wall time (real, 1 core):        {}", human_duration(wall));
    println!("virtual time (4-node testbed):   {}", human_duration(out.virtual_secs));
    println!("total shuffled:                  {}", human_bytes(out.shuffle_bytes));
    println!(
        "eigen iterations:                {} (converged={})",
        out.eigen_iterations, out.eigen_converged
    );
    println!("eigenvalues:                     {:.1?}", out.eigenvalues);
    println!("graph components:                {}", out.graph_components);
    println!("procrustes vs ground truth:      {perr:.3e}   (paper: 2.67e-5 at n=50k)");
    println!("residual variance (subsample):   {rv:.3e}");
    println!("\nper-stage metrics:\n{}", out.metrics_table);

    assert_eq!(out.graph_components, 1, "kNN graph must be connected");
    assert!(perr < 5e-3, "procrustes too high: {perr}");

    std::fs::create_dir_all("out")?;
    let mut dump = isospark::linalg::Matrix::zeros(n, 4);
    for i in 0..n {
        dump[(i, 0)] = out.embedding[(i, 0)];
        dump[(i, 1)] = out.embedding[(i, 1)];
        dump[(i, 2)] = truth[(i, 0)];
        dump[(i, 3)] = truth[(i, 1)];
    }
    io::write_csv(Path::new("out/swiss_e2e_embedding.csv"), &dump, Some(&["y1", "y2", "t", "h"]))?;
    println!("embedding + ground truth written to out/swiss_e2e_embedding.csv");
    println!("E2E OK");
    Ok(())
}
