//! Fit once, serve forever: fit a streaming model on a swiss roll, save
//! the artifact, load it back, stand up the embedding server on an
//! ephemeral loopback port, and query it through the bundled client.
//!
//! Run with: `cargo run --release --example serve_quickstart`

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::streaming::StreamingModel;
use isospark::data::swiss_roll;
use isospark::model::{FittedModel, ModelInfo};
use isospark::serve::{self, client, ServeConfig};

fn main() -> anyhow::Result<()> {
    // 1. Fit: the expensive part — distributed kNN, landmark geodesics,
    //    landmark MDS. Runs once.
    let ds = swiss_roll::euler_isometric(500, 42);
    let cfg = IsomapConfig { k: 10, d: 2, block: 64, ..Default::default() };
    let model =
        StreamingModel::fit(&ds.points, &cfg, 80, &ClusterConfig::local(), &Backend::Native)?
            .into_model();
    println!("fitted: n={} D={} landmarks={}", model.n(), model.dim(), model.num_landmarks());

    // 2. Save the versioned artifact and inspect it (what `isospark fit
    //    --save` / `isospark info --model` do).
    let dir = std::env::temp_dir().join("isospark_serve_quickstart");
    model.save(&dir)?;
    println!("{}", ModelInfo::inspect(&dir)?.render());

    // 3. Serve: load the artifact in a "different process" and put the
    //    HTTP front on it (what `isospark serve --model` does).
    let loaded = FittedModel::load(&dir)?;
    let handle = serve::start(
        loaded,
        Some(dir.clone()),
        None,
        &ServeConfig { threads: 2, ..Default::default() },
    )?;
    let addr = handle.addr();
    println!("serving on http://{addr}");

    // 4. Query: out-of-sample points from the same manifold, projected in
    //    O(k·m) each — no O(n³) pipeline rerun.
    let fresh = swiss_roll::euler_isometric(8, 97);
    let emb = client::embed(&addr, &fresh.points)?;
    for i in 0..emb.nrows() {
        println!("point {i}: ({:+.4}, {:+.4})", emb[(i, 0)], emb[(i, 1)]);
    }

    let (code, health) = client::get_json(&addr, "/healthz")?;
    println!("healthz {code}: {health}");
    let (_, metrics) = client::get_json(&addr, "/metrics")?;
    if let Some(lat) = metrics.get("embed_latency_us") {
        println!("served embeds: {}", lat.get("count").map(|c| c.to_string()).unwrap_or_default());
    }

    handle.shutdown();
    Ok(())
}
