//! Synthetic-EMNIST embedding (paper Fig. 5 at laptop scale).
//!
//! Generates 28×28 stroke-rendered digits (D = 784 — the paper's EMNIST
//! dimensionality), embeds them with the full pipeline, and reproduces the
//! paper's qualitative reading of the axes: one embedding direction tracks
//! the *slant* factor, digits separate into clusters, and curved digits
//! (0, 8) land away from straight ones (1, 4, 7). Prints ASCII digit
//! samples like the image insets of Fig. 5(b). Recorded in
//! EXPERIMENTS.md §F5.
//!
//! ```bash
//! cargo run --release --example emnist_digits
//! ```

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::isomap;
use isospark::data::emnist_synth;
use isospark::util::fmt::render_table;
use std::path::Path;

fn corr(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let (ma, mb) = (a.iter().sum::<f64>() / n, b.iter().sum::<f64>() / n);
    let (mut cov, mut va, mut vb) = (0.0, 0.0, 0.0);
    for (x, y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    cov / (va * vb).sqrt()
}

fn main() -> anyhow::Result<()> {
    let n = 512;
    let ds = emnist_synth::generate(n, 7);
    let cfg = IsomapConfig { k: 10, d: 2, block: 128, ..Default::default() };
    let backend =
        Backend::pjrt_from_dir(Path::new("artifacts")).unwrap_or(Backend::Native);
    println!("synthetic EMNIST: n={n} D={} | backend={}", ds.dim(), backend.name());

    // Show two sample digits (the Fig. 5b insets).
    let mut rng = isospark::util::Rng::seed(1);
    for digit in [4usize, 8] {
        println!("sample digit {digit} (slant +0.25):");
        let img = emnist_synth::render(digit, 0.25, 0.05, 0.0, &mut rng);
        print!("{}", emnist_synth::ascii(&img));
    }

    let out = isomap::run_with(&ds.points, &cfg, &ClusterConfig::paper_testbed(4), &backend)?;
    assert_eq!(out.graph_components, 1);
    let truth = ds.ground_truth.as_ref().unwrap();
    let labels = ds.labels.as_ref().unwrap();

    // Axis↔factor correlations. Curvature (straight vs curved strokes)
    // separates digit classes, so it is a *global* factor; slant varies
    // within each digit cluster (the paper reads it "from top to bottom of
    // the cluster", Fig. 5b), so it is measured per class.
    let curv: Vec<f64> = (0..n).map(|i| truth[(i, 0)]).collect();
    let mut best_curv = 0.0f64;
    for axis in 0..2 {
        let e: Vec<f64> = (0..n).map(|i| out.embedding[(i, axis)]).collect();
        let cc = corr(&e, &curv);
        println!("D{}: global corr(curvature) = {cc:+.3}", axis + 1);
        best_curv = best_curv.max(cc.abs());
    }
    // Within-class slant: for each digit, correlate slant with the best
    // embedding axis, then average over classes.
    let mut slant_sum = 0.0;
    let mut slant_cls = 0;
    for digit in 0..10usize {
        let idx: Vec<usize> = (0..n).filter(|&i| labels[i] == digit).collect();
        if idx.len() < 8 {
            continue;
        }
        let s: Vec<f64> = idx.iter().map(|&i| truth[(i, 1)]).collect();
        let best = (0..2)
            .map(|j| {
                let e: Vec<f64> = idx.iter().map(|&i| out.embedding[(i, j)]).collect();
                corr(&e, &s).abs()
            })
            .fold(0.0, f64::max);
        slant_sum += best;
        slant_cls += 1;
    }
    let best_slant = slant_sum / slant_cls as f64;
    println!("mean within-class |corr(slant)| over {slant_cls} digits: {best_slant:.3}");

    // Cluster table: per-digit centroids + intra/inter spread.
    let mut rows = vec![vec!["digit".into(), "n".into(), "D1".into(), "D2".into()]];
    let mut centroids = Vec::new();
    for digit in 0..10usize {
        let idx: Vec<usize> = (0..n).filter(|&i| labels[i] == digit).collect();
        let c: Vec<f64> = (0..2)
            .map(|j| idx.iter().map(|&i| out.embedding[(i, j)]).sum::<f64>() / idx.len() as f64)
            .collect();
        rows.push(vec![
            digit.to_string(),
            idx.len().to_string(),
            format!("{:+.2}", c[0]),
            format!("{:+.2}", c[1]),
        ]);
        centroids.push((digit, c, idx));
    }
    println!("{}", render_table(&rows));

    // Quantify clustering: mean distance to own centroid vs nearest other.
    let mut intra = 0.0;
    let mut cnt = 0;
    for (_, c, idx) in &centroids {
        for &i in idx {
            let d = (0..2).map(|j| (out.embedding[(i, j)] - c[j]).powi(2)).sum::<f64>().sqrt();
            intra += d;
            cnt += 1;
        }
    }
    intra /= cnt as f64;
    let mut min_inter = f64::INFINITY;
    for a in 0..centroids.len() {
        for b in (a + 1)..centroids.len() {
            let d = (0..2)
                .map(|j| (centroids[a].1[j] - centroids[b].1[j]).powi(2))
                .sum::<f64>()
                .sqrt();
            min_inter = min_inter.min(d);
        }
    }
    println!("mean intra-cluster radius: {intra:.3}; closest centroid pair: {min_inter:.3}");
    println!(
        "factor recovery: |corr| slant = {best_slant:.3}, curvature = {best_curv:.3} \
         (paper reads slant along D2, curvature along D1)"
    );
    assert!(best_slant > 0.3, "slant factor not captured");
    println!("EMNIST OK");
    Ok(())
}
