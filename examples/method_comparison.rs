//! Method comparison: exact Isomap vs L-Isomap vs LLE vs Streaming-Isomap
//! on the same manifolds — the paper's §V/§VI discussion made concrete.
//! Reports wall time, Procrustes (isometric methods), and
//! trustworthiness/continuity (all methods) side by side.
//!
//! ```bash
//! cargo run --release --example method_comparison
//! ```

use isospark::backend::Backend;
use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::{isomap, landmark, lle, streaming::StreamingModel};
use isospark::data::swiss_roll;
use isospark::eval;
use isospark::linalg::Matrix;
use isospark::util::fmt::render_table;
use isospark::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let n = 800;
    let cfg = IsomapConfig { k: 10, d: 2, block: 128, ..Default::default() };
    let cluster = ClusterConfig::local();
    let be = Backend::Native;

    let mut rows = vec![vec![
        "dataset".to_string(),
        "method".to_string(),
        "wall".to_string(),
        "procrustes".to_string(),
        "trust".to_string(),
        "cont".to_string(),
    ]];

    for ds in [swiss_roll::euler_isometric(n, 3), swiss_roll::s_curve(n, 3)] {
        let truth = ds.ground_truth.as_ref().unwrap();
        let mut push = |method: &str, secs: f64, y: &Matrix, isometric: bool| {
            let p = if isometric {
                format!("{:.2e}", eval::procrustes(truth, y))
            } else {
                "n/a".to_string()
            };
            let (t, c) = eval::trustworthiness_continuity(&ds.points, y, 10, 400);
            rows.push(vec![
                ds.name.clone(),
                method.to_string(),
                format!("{:.0} ms", secs * 1e3),
                p,
                format!("{t:.3}"),
                format!("{c:.3}"),
            ]);
        };

        let sw = Stopwatch::start();
        let exact = isomap::run_with(&ds.points, &cfg, &cluster, &be)?;
        push("isomap (exact)", sw.secs(), &exact.embedding, true);

        let sw = Stopwatch::start();
        let lm = landmark::run(&ds.points, &cfg, n / 8, &cluster, &be)?;
        push("l-isomap (m=n/8)", sw.secs(), &lm.embedding, true);

        let sw = Stopwatch::start();
        let ll = lle::run(&ds.points, &cfg, &cluster, &be)?;
        push("lle", sw.secs(), &ll.embedding, false);

        let sw = Stopwatch::start();
        let model = StreamingModel::fit(&ds.points, &cfg, n / 8, &cluster, &be)?;
        push("streaming (batch)", sw.secs(), &model.batch_embedding, true);
    }

    println!("{}", render_table(&rows));
    println!(
        "notes: LLE is not isometric, so Procrustes against the latent\n\
         rectangle is not meaningful — rank-based trustworthiness/continuity\n\
         are the comparable scores. Streaming-batch ≈ L-Isomap by design."
    );
    Ok(())
}
