//! Regenerate the paper's scalability evaluation (Tables I–III + Fig. 6)
//! on the simulated 25-node GbE testbed, with the cost model calibrated
//! from this machine's real kernels. Also validates the projection against
//! a *real* engine run at small n. Recorded in EXPERIMENTS.md §T1–T3/§F6.
//!
//! ```bash
//! cargo run --release --example scale_table
//! ```

use isospark::config::{ClusterConfig, IsomapConfig};
use isospark::coordinator::isomap;
use isospark::data::swiss_roll;
use isospark::sim::{self, CostModel, Workload};
use isospark::util::fmt::render_table;

fn main() -> anyhow::Result<()> {
    println!("calibrating cost model from native kernels (b=256)…");
    let model = CostModel::calibrate(256);
    println!(
        "  coefficients (s/elem-op): dist={:.2e} minplus={:.2e} fw={:.2e} gemm={:.2e}\n",
        model.dist, model.minplus, model.fw, model.gemm
    );

    let nodes = [2usize, 4, 8, 12, 16, 20, 24];
    let suite = Workload::paper_suite(1500);

    // ---- Table I ----
    let mut rows = vec![{
        let mut h = vec!["Name".to_string()];
        h.extend(nodes.iter().map(|p| p.to_string()));
        h
    }];
    let mut per_suite: Vec<Vec<Option<f64>>> = Vec::new();
    for w in &suite {
        let mut row = vec![w.name.clone()];
        let mut per = Vec::new();
        for &p in &nodes {
            let proj = sim::project(w, &ClusterConfig::paper_testbed(p), &model);
            per.push(proj.total_secs);
            row.push(proj.total_secs.map_or("-".into(), |s| format!("{:.2}", s / 60.0)));
        }
        per_suite.push(per);
        rows.push(row);
    }
    println!("== Table I: execution time (virtual minutes) ==\n{}", render_table(&rows));

    // ---- Table II ----
    let mut rows2 = rows[..1].to_vec();
    for (w, per) in suite.iter().zip(&per_suite) {
        let base = per.iter().flatten().next().cloned();
        let mut row = vec![w.name.clone()];
        for v in per {
            row.push(match (base, v) {
                (Some(b), Some(t)) => format!("{:.2}", b / t),
                _ => "-".into(),
            });
        }
        rows2.push(row);
    }
    println!("== Table II: relative speedup ==\n{}", render_table(&rows2));

    // ---- Table III ----
    let mut rows3 = rows[..1].to_vec();
    for (w, per) in suite.iter().zip(&per_suite) {
        let base = per.iter().zip(&nodes).find_map(|(v, &p)| v.map(|t| (t, p)));
        let mut row = vec![w.name.clone()];
        for (v, &p) in per.iter().zip(&nodes) {
            row.push(match (base, v) {
                (Some((tb, pb)), Some(t)) => format!("{:.2}", (tb / t) * pb as f64 / p as f64),
                _ => "-".into(),
            });
        }
        rows3.push(row);
    }
    println!("== Table III: relative efficiency ==\n{}", render_table(&rows3));

    // ---- Fig. 6: block-size sweep on Swiss75 @ 24 nodes ----
    let mut rows6 = vec![vec!["b".to_string(), "q".to_string(), "total".to_string(), "apsp".to_string()]];
    let mut best: Option<(usize, f64)> = None;
    for b in [500usize, 750, 1000, 1500, 2000, 2500, 3000, 4000] {
        let w = Workload::new("Swiss75", 75_000, 3, b);
        let proj = sim::project(&w, &ClusterConfig::paper_testbed(24), &model);
        let t = proj.total_secs.unwrap();
        if best.map_or(true, |(_, bt)| t < bt) {
            best = Some((b, t));
        }
        rows6.push(vec![
            b.to_string(),
            75_000usize.div_ceil(b).to_string(),
            format!("{:.2} min", t / 60.0),
            format!("{:.2} min", proj.apsp_secs / 60.0),
        ]);
    }
    println!("== Fig. 6: block-size sweep (Swiss75, 24 nodes) ==\n{}", render_table(&rows6));
    let (bb, _) = best.unwrap();
    println!("sweet spot: b = {bb} (paper: b = 1500)\n");

    // ---- Projection sanity: real engine run vs projection at small n ----
    println!("validating projection against a real engine run (n=1024, b=128, 4 nodes)…");
    let ds = swiss_roll::euler_isometric(1024, 3);
    let cfg = IsomapConfig { k: 10, d: 2, block: 128, ..Default::default() };
    let out = isomap::run(&ds.points, &cfg, &ClusterConfig::paper_testbed(4))?;
    let w = Workload { eigen_iters: out.eigen_iterations, ..Workload::new("v", 1024, 3, 128) };
    let proj = sim::project(&w, &ClusterConfig::paper_testbed(4), &CostModel::calibrate(128));
    let (a, b) = (out.virtual_secs, proj.total_secs.unwrap());
    println!("  engine virtual time: {a:.2}s | projected: {b:.2}s | ratio {:.2}", a / b);
    Ok(())
}
